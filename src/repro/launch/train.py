"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 200 --compress l1:2.0 --debias-steps 50 --ckpt-dir /tmp/ckpt

Runs compressed training (the paper's SpC pipeline) on any zoo architecture.
On this CPU container use --reduced; on a pod, point --mesh at the production
mesh and the same script drives all hosts (SPMD).

``--sparse`` switches to SpC-Retrain (train *into* BlockCSR): the prox is the
plan-aligned block group-l1 (exact zero blocks on the serving (out, in) BCSR
grid), compression happens WITHOUT a prune step, the debias phase retrains
the compressed model itself (masks frozen, only BlockCSR.data updates, dw via
SDDMM at resident slots), and the final artifact is a compressed checkpoint
under ``<ckpt-dir>/compressed`` that ``launch/serve --sparse --ckpt-dir``
loads and serves from directly:

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --sparse --steps 60 --debias-steps 20 --compress group_l1:0.05 \
        --block 8 64 --ckpt-dir /tmp/spc

``--quantize-bits 8|4`` adds Deep Compression stage 2 on top: after debias,
the BlockCSR block data is k-means palette-quantized (``PaletteBCSR``,
uint8 / nibble-packed codes + per-layer palette) and the compressed
checkpoint stores — and serving loads — the quantized form directly.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.core import metrics as metrics_lib
from repro.core.optimizers import prox_adam, prox_rmsprop, prox_sgd
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import mesh_from_flag
from repro.models import frontends
from repro.models.model_zoo import build
from repro.sparse.compress import (CompressionPlan, compressed_size_bytes,
                                   compression_summary, format_size_report,
                                   make_plan_prox, quantize_compressed)
from repro.train.loop import (LoopConfig, run_spc_pipeline,
                              run_spc_retrain_pipeline, train_loop)
from repro.train.state import TrainState
from repro.train.step import make_train_step


def parse_compress(spec: str):
    """'l1:2.0' | 'group_l1:0.5' | 'none'."""
    if spec == "none":
        return "none", 0.0
    kind, lam = spec.split(":")
    return kind, float(lam)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--debias-steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress", default="l1:1.0")
    ap.add_argument("--optimizer", default="prox_adam",
                    choices=["prox_adam", "prox_rmsprop", "prox_sgd"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--mesh", default="none",
                    help="none | single | multi | DATA,MODEL. SPMD training "
                         "mesh: 'single'/'multi' are the production pod "
                         "meshes, 'D,M' a host mesh over existing devices "
                         "(CI forces 4 with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=4 and runs --mesh 2,2). "
                         "With --sparse the COMPRESSED pytree is sharded "
                         "too: BlockCSR/PaletteBCSR block stores split "
                         "along the block-row slot axis (the dense out-dim "
                         "rule), index/gather tables and palettes "
                         "replicate, and the sharded CompressedParams "
                         "flows through debias retraining and the "
                         "compressed checkpoint unchanged")
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--telemetry-out", default="",
                    help="stream per-log-step training telemetry to this "
                         "JSONL file: loss/grad-norm metrics, the group-l1 "
                         "penalty, live per-layer block sparsity on the "
                         "serving BCSR grid, and debias progress — one "
                         "phase-tagged record per line, flushed as written "
                         "(tail-able while training runs)")
    ap.add_argument("--sparse", action="store_true",
                    help="SpC-Retrain into BlockCSR: prox-SpC training with "
                         "plan-aligned block group-l1 (exact zero blocks on "
                         "the serving (out, in) BCSR grid, no prune step), "
                         "then mask-frozen debias retraining ON the "
                         "compressed params (only BlockCSR.data updates, dw "
                         "via SDDMM at resident slots), then a compressed "
                         "checkpoint under <ckpt-dir>/compressed that "
                         "launch/serve --sparse --ckpt-dir loads "
                         "template-free")
    ap.add_argument("--quantize-bits", type=int, default=0, choices=[0, 4, 8],
                    help="Deep Compression stage 2 (with --sparse): after "
                         "debias, k-means palette-quantize BlockCSR block "
                         "data to PaletteBCSR at this bit width (0 = off); "
                         "the checkpoint then serves from the quantized "
                         "form directly")
    ap.add_argument("--block", type=int, nargs=2, default=(8, 64),
                    metavar=("BR", "BC"),
                    help="BCSR block on the (out, in) view (--sparse)")
    ap.add_argument("--min-block-sparsity", type=float, default=0.3,
                    help="dense fallback below this zero-block fraction")
    args = ap.parse_args(argv)
    if args.quantize_bits and not args.sparse:
        raise SystemExit("--quantize-bits requires --sparse (the palette "
                         "quantizes the compressed BlockCSR block store)")

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    model = build(cfg, reduced=args.reduced)
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    kind, lam = parse_compress(args.compress)
    opt_cls = {"prox_adam": prox_adam, "prox_rmsprop": prox_rmsprop,
               "prox_sgd": prox_sgd}[args.optimizer]

    plan = CompressionPlan(block=tuple(args.block),
                           min_sparsity=args.min_block_sparsity)
    if args.sparse:
        # SpC-Retrain: block group-l1 on the exact compression grid — the
        # regularizer, not a prune pass, creates the BCSR zero blocks
        if kind != "group_l1" or lam <= 0:
            raise SystemExit(
                f"--sparse trains into BlockCSR via block group-l1; pass "
                f"--compress group_l1:<lam> with lam > 0 (got {args.compress!r})")
        opt = opt_cls(args.lr, lam=lam, prox_fn=make_plan_prox(plan))
    else:
        opt = opt_cls(args.lr, lam=lam,
                      prox_name=kind if kind != "none" else "none")
    opt_debias = opt_cls(args.lr, lam=0.0)

    data_cfg = TokenStreamConfig(vocab=cfg.vocab, seq_len=args.seq,
                                 global_batch=args.batch)

    def batch_fn(step):
        b = token_batch(data_cfg, step)
        if cfg.frontend != "none":
            emb = frontends.synthetic_embeddings(
                jax.random.PRNGKey(step), cfg, args.batch, args.seq)
            b = {"inputs": emb, "labels": b["labels"]}
        return b

    mesh = mesh_from_flag(args.mesh)
    if mesh is not None:
        # place master params once; train steps then carry the shardings
        # (the compressed pipeline re-places after compress_params — see
        # run_spc_retrain_pipeline)
        params = jax.device_put(params, shd.param_shardings(params, mesh))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        # train_loop resumes from the newest checkpoint: compression/prox
        # flags are NOT re-applied to already-trained steps, so a rerun
        # with different hyperparameters into the same dir silently keeps
        # the old trajectory (at latest_step >= --steps the SpC phase is
        # skipped entirely). Make that visible.
        logging.getLogger("repro.launch.train").warning(
            "resuming from existing checkpoint (step %d) in %s — "
            "hyperparameter flags must match the original run; use a fresh "
            "--ckpt-dir to restart training", ckpt.latest_step(),
            args.ckpt_dir)

    def make_step(o, param_transform=None):
        step = make_train_step(model, o, param_transform=param_transform)
        return jax.jit(step, donate_argnums=(0,))

    telemetry = None
    if args.telemetry_out:
        from repro.obs import TrainTelemetry
        telemetry = TrainTelemetry(args.telemetry_out)

    ctx = shd.use_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        if args.sparse:
            cp, hist_spc, hist_db, report = run_spc_retrain_pipeline(
                params, make_step, opt, opt_debias, batch_fn,
                spc_steps=args.steps, debias_steps=args.debias_steps,
                plan=plan, checkpointer=ckpt, log_every=args.log_every,
                telemetry=telemetry)
            if args.quantize_bits:
                # Deep Compression stage 2, the LAST stage: quantize after
                # debias so retraining saw fp block data; the checkpoint
                # below stores (and serve loads) the palette form directly
                cp = quantize_compressed(cp, bits=args.quantize_bits)
                report["palette_bytes"] = compressed_size_bytes(cp)
            print("compression:", json.dumps(report, indent=1))
            if hist_spc:
                print(f"loss: {hist_spc[0]['loss']:.4f} -> "
                      f"{hist_spc[-1]['loss']:.4f}")
            print(compression_summary(cp))
            print(format_size_report(report["dense_bytes"],
                                     report["bcsr_bytes"],
                                     report.get("palette_bytes")))
            if args.ckpt_dir:
                cdir = os.path.join(args.ckpt_dir, "compressed")
                final_step = args.steps + args.debias_steps
                path = Checkpointer(cdir, keep_n=2).save(
                    final_step, cp,
                    extra={"plan": dataclasses.asdict(cp.plan),
                           "arch": args.arch, "reduced": args.reduced})
                print(f"compressed checkpoint: {path}")
            if telemetry is not None:
                telemetry.close()
                print(f"telemetry: {telemetry.n_records} records -> "
                      f"{args.telemetry_out}")
            return cp, hist_spc, hist_db, report

        state, hist_spc, hist_db, report = run_spc_pipeline(
            params, make_step, opt, opt_debias, batch_fn,
            spc_steps=args.steps, debias_steps=args.debias_steps,
            checkpointer=ckpt, log_every=args.log_every,
            telemetry=telemetry, sparsity_block=tuple(args.block))

    print("compression:", json.dumps(report, indent=1))
    if hist_spc:
        print(f"loss: {hist_spc[0]['loss']:.4f} -> {hist_spc[-1]['loss']:.4f}")
    table = metrics_lib.layer_compression(state.params)
    print(metrics_lib.format_table(table, "layer-wise compression:"))
    if telemetry is not None:
        telemetry.close()
        print(f"telemetry: {telemetry.n_records} records -> "
              f"{args.telemetry_out}")
    return state, hist_spc, hist_db, report


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
