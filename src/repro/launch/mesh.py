"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests / benches see 1 CPU device while
only the dry-run forces 512 placeholder devices).
"""
from __future__ import annotations

import jax
import numpy as np


def _axis_type_kwargs(n_axes: int) -> dict:
    """Explicit Auto axis types where supported; older jax (< AxisType) is
    Auto-by-default and rejects the kwarg."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n], **_axis_type_kwargs(2))


def mesh_from_flag(spec: str):
    """Resolve a CLI ``--mesh`` flag to a Mesh (or None).

    'none'   -> no mesh (single device),
    'single' -> production (data=16, model=16) pod,
    'multi'  -> production (pod=2, data=16, model=16),
    'D,M'    -> host mesh (data=D, model=M) over existing devices — the
                multi-device CI shape (XLA_FLAGS=
                --xla_force_host_platform_device_count=N forces N host
                devices before jax import).

    All variants serve both dense and compressed params: BlockCSR /
    PaletteBCSR leaves shard their block store along the block-row slot
    axis and replicate index/gather/palette arrays
    (distributed/sharding.param_shardings).
    """
    if spec in (None, "", "none"):
        return None
    if spec == "single":
        return make_production_mesh()
    if spec == "multi":
        return make_production_mesh(multi_pod=True)
    try:
        data, model = (int(t) for t in spec.split(","))
    except ValueError:
        raise SystemExit(
            f"--mesh must be none|single|multi|DATA,MODEL — got {spec!r}")
    if len(jax.devices()) < data * model:
        raise SystemExit(
            f"--mesh {spec} needs {data * model} devices but only "
            f"{len(jax.devices())} present; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model} "
            "before launch to force host devices")
    return make_host_mesh(data, model)
