"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests / benches see 1 CPU device while
only the dry-run forces 512 placeholder devices).
"""
from __future__ import annotations

import jax
import numpy as np


def _axis_type_kwargs(n_axes: int) -> dict:
    """Explicit Auto axis types where supported; older jax (< AxisType) is
    Auto-by-default and rejects the kwarg."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n], **_axis_type_kwargs(2))
