"""Serving launcher: batched generation, optionally end-to-end from
compressed (BCSR) weights — the paper's inference path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 32 --sparse

``--sparse`` (without a checkpoint) block-magnitude-prunes the random-init
model on the serving BCSR grid, builds ``CompressedParams`` (attention
QKV/O, MLP, and untied head as BlockCSR; dense fallback for matrices that
don't compress) and serves from it: every compressed projection dispatches
``sparse_matmul`` on the prefill and decode paths. Add ``--quantize-bits
8|4`` for Deep Compression stage 2: block data is palette-quantized and
served from ``PaletteBCSR`` (uint8 / nibble-packed codes, palette lookup
fused into the kernel).

``--ckpt-dir <dir>`` instead serves the full trained pipeline's artifact —
a compressed checkpoint written by ``launch/train --sparse`` (prox-SpC
trained into BlockCSR, mask-frozen debias retraining on the compressed
params, optionally palette-quantized) — template-free and without
densifying; no pruning happens here because the sparsity came from
training. The manifest's arch/reduced tags are validated against the serve
flags.

Either way the per-layer size breakdown (``compression_summary``) and the
one-line dense/bcsr/palette byte report are printed; every number follows
docs/size_accounting.md.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.core.metrics import model_size_bytes
from repro.distributed import sharding as shd
from repro.launch.mesh import mesh_from_flag
from repro.models.model_zoo import build
from repro.serve.step import generate
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   compressed_size_bytes, compression_summary,
                                   format_size_report, iter_bcsr,
                                   prune_blocks_for_plan)
from repro.sparse.formats import PaletteBCSR


def _report_sizes(cp, dense_b: int):
    """Per-layer breakdown + one-line byte report (docs/size_accounting.md):
    ``bcsr`` is always the fp32 BlockCSR total; when any layer is
    palette-quantized the actual (smaller) serving total is reported as
    ``palette``."""
    from repro.sparse.compress import bcsr_equiv_size_bytes

    actual_b = compressed_size_bytes(cp)
    bcsr_b = bcsr_equiv_size_bytes(cp)
    quantized = any(isinstance(m, PaletteBCSR) for _, m in iter_bcsr(cp))
    print(compression_summary(cp))
    print(format_size_report(dense_b, bcsr_b,
                             actual_b if quantized else None))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparse", action="store_true",
                    help="serve from the compressed form: with --ckpt-dir, "
                         "load a launch/train --sparse artifact (prox-SpC "
                         "trained into BlockCSR, mask-frozen debias, "
                         "optionally palette-quantized) template-free; "
                         "without one, block-prune the random init on the "
                         "serving BCSR grid and compress it")
    ap.add_argument("--quantize-bits", type=int, default=0, choices=[0, 4, 8],
                    help="palette-quantize the compressed block data "
                         "(PaletteBCSR, Deep Compression stage 2) before "
                         "serving — prune path only; checkpoints carry "
                         "their own quantization")
    ap.add_argument("--sparsity", type=float, default=0.9,
                    help="fraction of weight blocks pruned before compression")
    ap.add_argument("--block", type=int, nargs=2, default=(8, 128),
                    metavar=("BR", "BC"), help="BCSR block (out, in) view")
    ap.add_argument("--min-block-sparsity", type=float, default=0.5,
                    help="dense fallback below this zero-block fraction")
    ap.add_argument("--ckpt-dir", default="",
                    help="serve a compressed checkpoint from launch/train "
                         "--sparse (looks in <dir>/compressed, then <dir>)")
    ap.add_argument("--mesh", default="none",
                    help="none | single | multi | DATA,MODEL. Serve under "
                         "an SPMD mesh: 'single'/'multi' are the production "
                         "pod meshes, 'D,M' a host mesh (the multi-device "
                         "CI runs --mesh 2,2 on 4 forced host devices). "
                         "Compressed (--sparse / --ckpt-dir) serving shards "
                         "the BCSR/PaletteBCSR pytree: block stores split "
                         "along the block-row slot axis per the dense "
                         "out-dim rule, index/gather tables and palettes "
                         "replicate, and prefill/decode run the same "
                         "sparse_matmul kernels under GSPMD — logits match "
                         "the unsharded run")
    ap.add_argument("--logits-out", default="",
                    help="save the prefill logits (B, vocab) to this .npy "
                         "path — the CI sharded-vs-single-host parity gate "
                         "compares these to 1e-4")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation when sampling (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus (top-p) filtering when sampling (1 = off)")
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(serve/engine.py): slot resource pools (paged KV "
                         "for attention incl. int8, slot-indexed state for "
                         "RWKV/RG-LRU), chunked prefill, priority classes "
                         "with preempt-and-requeue over a fixed-capacity "
                         "slot batch — many concurrent mixed-length "
                         "requests instead of one fixed batch")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="engine slot capacity (concurrent requests)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens consumed per slot per engine tick; "
                         "prompts longer than this prefill across ticks, "
                         "interleaved with running decodes")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page length (tokens) of the paged cache")
    ap.add_argument("--first-chunk", type=int, default=0,
                    help="jumbo width for the FIRST prefill chunk of a "
                         "long prompt (> --prefill-chunk; 0 = off) — a "
                         "third compiled tick width that cuts TTFT")
    ap.add_argument("--attn-backend", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="paged-attention kernel for the engine step: "
                         "'pallas' = fused page-gather flash-decode kernel "
                         "(interpret mode off-TPU), 'ref' = jnp gather "
                         "oracle, 'auto' = pallas on TPU, ref elsewhere")
    ap.add_argument("--kv-splits", type=int, default=1,
                    help="flash-decode KV-split lanes per slot on the "
                         "pallas backend")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="with --engine: radix-tree prefix caching — "
                         "requests sharing a prompt prefix share physical "
                         "KV pages (refcounted, copy-on-write on the first "
                         "diverging page), so a shared system prompt is "
                         "prefilled once (attention-layer models only)")
    ap.add_argument("--priority", default="standard",
                    help="default scheduling class for --engine requests: "
                         "interactive | standard | batch, or an int >= 0 "
                         "(0 = most important; lower classes can be "
                         "preempted). Per-request override via the "
                         '--requests file\'s "priority" field')
    ap.add_argument("--requests", default="",
                    help="JSON request mix for --engine: a list of "
                         '{"prompt_len": N, "gen": M} (random prompt) or '
                         '{"prompt": [ids], "gen": M} entries, each with an '
                         'optional "priority" (class name or int >= 0, '
                         "default --priority); default mix is --batch "
                         "copies of --prompt-len/--gen")
    ap.add_argument("--parity-check", action="store_true",
                    help="with --engine (greedy): also run every request "
                         "through the sequential generate() path and fail "
                         "on any per-token mismatch")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --engine: serve through N data-parallel "
                         "engine replicas behind the asyncio router "
                         "(serve/router.py). Replicas share the same "
                         "immutable (compressed) params — the smaller the "
                         "model, the more replicas fit per host")
    ap.add_argument("--metrics-out", default="",
                    help="with --engine: write the live metrics registry "
                         "after the run — Prometheus text exposition, or a "
                         "JSON snapshot when the path ends in .json "
                         "(scheduler admissions/preemptions, page occupancy, "
                         "prefix-cache hits, per-tick widths, router "
                         "dispatch/failover)")
    ap.add_argument("--trace-out", default="",
                    help="with --engine: write a Chrome trace-event / "
                         "Perfetto JSON of the run (per-request lifecycle "
                         "spans + per-tick engine spans) — load it at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--profile", nargs="?", const="", default=None,
                    metavar="JAX_TRACE_DIR",
                    help="with --engine: time the jitted tick and every "
                         "Pallas kernel entry point "
                         "(block_until_ready-bracketed wall clock) and "
                         "print the summary; pass a directory to also "
                         "capture a jax.profiler trace there")
    ap.add_argument("--route", default="prefix",
                    choices=["prefix", "least-loaded", "round-robin"],
                    help="router dispatch policy: 'prefix' = "
                         "rendezvous-hash the leading page-aligned prompt "
                         "tokens so shared system prompts stay on the "
                         "replica whose radix prefix cache holds them "
                         "(falls back to least-loaded for short prompts "
                         "and failed replicas), 'least-loaded' = queue "
                         "depth + reserved pages, 'round-robin' = modulo "
                         "counter")
    args = ap.parse_args(argv)
    if args.quantize_bits and (not args.sparse or args.ckpt_dir):
        raise SystemExit(
            "--quantize-bits applies to the --sparse prune path only "
            "(checkpoints carry their own quantization; without --sparse "
            "nothing is compressed to quantize)")

    model = build(args.arch, reduced=args.reduced)
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    mesh = mesh_from_flag(args.mesh)
    mesh_ctx = shd.use_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()

    if args.ckpt_dir:
        # --ckpt-dir always means "serve this compressed checkpoint" (with
        # or without --sparse): silently serving random init instead of the
        # artifact the user pointed at would be a footgun
        cdir = os.path.join(args.ckpt_dir, "compressed")
        if not os.path.isdir(cdir):
            cdir = args.ckpt_dir
        ckpt = Checkpointer(cdir)
        latest = ckpt.latest_step()
        if latest is None:
            raise SystemExit(f"no checkpoints found in {cdir}")
        extra = ckpt.manifest(latest).get("extra") or {}
        if extra.get("arch") not in (None, args.arch) or \
                extra.get("reduced") not in (None, args.reduced):
            raise SystemExit(
                f"checkpoint was trained with arch={extra.get('arch')!r} "
                f"reduced={extra.get('reduced')} but serve got "
                f"arch={args.arch!r} reduced={args.reduced}")
        params = ckpt.restore_compressed(mesh=mesh)
        # dense byte count from shapes only — don't allocate a dense model
        # just to print the ratio
        shapes = jax.eval_shape(model.init, key)
        dense_b = sum(int(l.size) * l.dtype.itemsize
                      for l in jax.tree.leaves(shapes))
        _report_sizes(params, dense_b)
    elif args.sparse:
        params = model.init(key)
        plan = CompressionPlan(
            block=tuple(args.block), min_sparsity=args.min_block_sparsity,
            quantize_bits=args.quantize_bits or None,
            # pack slot counts so the block store divides the mesh axes and
            # shards (instead of silently replicating on odd slot counts)
            slot_multiple=(int(np.lcm.reduce(
                [int(s) for s in mesh.shape.values()]))
                if mesh is not None else None))
        params = prune_blocks_for_plan(params, plan, args.sparsity)
        dense_b = model_size_bytes(params, sparse=False)
        params = compress_params(params, plan)   # PaletteBCSR when quantizing
        _report_sizes(params, dense_b)
    else:
        params = model.init(key)

    if mesh is not None and not args.ckpt_dir:
        # checkpoint restore placed sharded already (restore_compressed);
        # the prune/dense paths place here — dense rules for raw leaves,
        # block-row slot sharding for BCSR/PaletteBCSR stores
        params = jax.device_put(params, shd.param_shardings(params, mesh))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                0, cfg.vocab)
    with mesh_ctx:
        if args.logits_out:
            cache = model.init_cache(args.batch, args.prompt_len + args.gen)
            logits, _ = jax.jit(model.prefill)(params, prompt, cache)
            np.save(args.logits_out,
                    np.asarray(jax.device_get(logits)).astype(np.float32))
            print(f"prefill logits -> {args.logits_out}")
        if args.engine:
            return _run_engine(model, params, args)
        t0 = time.perf_counter()
        out = generate(model, params, prompt, args.gen,
                       sampling=_sampling(args), rng=jax.random.PRNGKey(1))
        dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0, :16].tolist())
    return out


def _sampling(args):
    """The one place serve flags become a typed SamplingParams."""
    from repro.serve.api import SamplingParams
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p)


def _load_requests(args, vocab: int) -> list:
    """``api.Request`` list from --requests JSON (or the --batch/
    --prompt-len/--gen defaults), validated against the typed schema
    (``api.parse_request_file``) with actionable errors. Random prompts are
    seeded per request index so the mix is reproducible."""
    import json

    from repro.serve import api

    if args.requests:
        with open(args.requests) as f:
            try:
                spec = json.load(f)
            except json.JSONDecodeError as e:
                raise SystemExit(f"--requests {args.requests}: not valid "
                                 f"JSON ({e})")
    else:
        spec = [{"prompt_len": args.prompt_len, "gen": args.gen}
                for _ in range(args.batch)]
    try:
        entries = api.parse_request_file(spec, default_gen=args.gen,
                                         default_priority=args.priority)
    except api.ApiValidationError as e:
        raise SystemExit(f"--requests {args.requests or '(defaults)'}: {e}")
    out = []
    for i, e in enumerate(entries):
        ids = e["prompt"]
        if ids is None:
            ids = np.asarray(jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(1234), i),
                (e["prompt_len"],), 0, vocab), np.int32)
        out.append(api.Request(prompt=ids,
                               max_new_tokens=e["max_new_tokens"],
                               eos_id=e["eos_id"], priority=e["priority"],
                               sampling=e["sampling"]))
    return out


def _engine_config(args, max_seq: int):
    """The one place serve flags become an ``EngineConfig`` — also what
    the router replicates (all replicas share this single value)."""
    from repro.serve.engine import EngineConfig
    return EngineConfig(max_batch=args.max_batch,
                        prefill_chunk=args.prefill_chunk,
                        page_size=args.page_size, max_seq_len=max_seq,
                        first_chunk=args.first_chunk or None,
                        attn_backend=args.attn_backend,
                        kv_splits=args.kv_splits,
                        prefix_cache=args.prefix_cache,
                        sampling=_sampling(args))


def _ms(x) -> str:
    """Milliseconds for printing — percentiles over an empty record set
    are None (obs.metrics.pct), shown as '-'."""
    return "-" if x is None else f"{x * 1e3:.0f}"


def _print_slo_classes(s):
    if len(s["by_class"]) > 1 or s.get("n_preemptions"):
        for c, cs in s["by_class"].items():
            print(f"  class {c}: {cs['n_requests']} requests "
                  f"({cs['n_preempted']} preempted) | ttft p50/p95 "
                  f"{_ms(cs['ttft_p50_s'])}/{_ms(cs['ttft_p95_s'])}ms"
                  f" | latency p50/p95 {_ms(cs['latency_p50_s'])}/"
                  f"{_ms(cs['latency_p95_s'])}ms")


def _telemetry(args):
    """--trace-out / --profile flags to (tracer, profiler) — None when the
    flag is off (the engine then uses its zero-overhead null paths)."""
    from repro.obs import Profiler, Tracer
    tracer = Tracer() if args.trace_out else None
    profiler = (Profiler(jax_trace_dir=args.profile or None)
                if args.profile is not None else None)
    return tracer, profiler


def _save_telemetry(args, save_prom, save_json, tracer, profiler):
    """Write --metrics-out / --trace-out artifacts and print the profile
    summary. ``save_prom(path)`` / ``save_json(path)`` are the caller's
    exporters (engine registry, or the router's merged fleet exposition)."""
    if args.metrics_out:
        if args.metrics_out.endswith(".json"):
            save_json(args.metrics_out)
        else:
            save_prom(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out and tracer is not None:
        tracer.save(args.trace_out)
        print(f"trace -> {args.trace_out} ({len(tracer.events)} events)")
    if profiler is not None:
        print(profiler.format_summary())


def _check_parity(model, params, args, requests, results):
    if args.temperature > 0:
        raise SystemExit("--parity-check needs greedy decoding "
                         "(--temperature 0): generate() and the engine "
                         "draw from different rng streams")
    for rid, r in enumerate(requests):
        ids, gen = r.prompt_ids, r.max_new_tokens
        ref = np.asarray(generate(model, params, ids[None, :], gen))[0]
        got = np.asarray(results[rid])
        if r.eos_id is not None and r.eos_id in ref.tolist():
            ref = ref[:ref.tolist().index(r.eos_id) + 1]
        if not np.array_equal(ref, got):
            raise SystemExit(
                f"engine-vs-generate token mismatch for request {rid} "
                f"(prompt_len={len(ids)}): {got.tolist()} != "
                f"{ref.tolist()}")
    print(f"engine-vs-generate parity OK ({len(requests)} requests)")


def _run_engine(model, params, args):
    """The --engine path: continuous batching over the slot resource pools
    (paged KV for attention layers, slot-indexed state for recurrent);
    with --replicas N > 1, N such engines behind the asyncio router."""
    from repro.serve.engine import ServeEngine

    requests = _load_requests(args, model.cfg.vocab)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    config = _engine_config(args, max_seq)
    try:
        if args.replicas > 1:
            return _run_router(model, params, args, config, requests)
        tracer, profiler = _telemetry(args)
        engine = ServeEngine(model, params, config,
                             rng=jax.random.PRNGKey(1),
                             tracer=tracer, profiler=profiler)
    except NotImplementedError as e:
        raise SystemExit(f"--engine: {e}")
    pb = engine.pool_bytes
    print(f"engine pools: kv_pages={pb['kv_page_bytes'] / 2**20:.2f} MiB "
          f"recurrent_state={pb['state_slot_bytes'] / 2**20:.2f} MiB "
          f"({engine.config.max_batch} slots)")
    from repro.serve.api import ApiValidationError
    try:
        with (profiler if profiler is not None
              else contextlib.nullcontext()):
            out = engine.run(requests)
    except ApiValidationError as e:
        raise SystemExit(f"--engine: {e}")
    s = out["stats"]
    print(f"engine: {s['n_requests']} requests "
          f"({s['n_prompt']} prompt + {s['n_generated']} new tokens) in "
          f"{s['wall_s']:.2f}s = {s['tok_s']:.1f} tok/s | "
          f"ttft p50/p95 {_ms(s['ttft_p50_s'])}/{_ms(s['ttft_p95_s'])}ms"
          f" | latency p50/p95 {_ms(s['latency_p50_s'])}/"
          f"{_ms(s['latency_p95_s'])}ms | {s['n_ticks']} ticks, "
          f"{s['n_prefill_chunks']} prefill chunks | pools "
          f"kv={s['kv_page_bytes']} state={s['state_slot_bytes']} bytes")
    if len(s["by_class"]) > 1 or s["n_preemptions"]:
        _print_slo_classes(s)
        print(f"  {s['n_preemptions']} preemptions")
    if args.prefix_cache:
        print(f"  prefix cache: hit rate {s['prefix_hit_rate']:.1%} "
              f"({s['n_cached_tokens']} prompt tokens served from cache)")
    _save_telemetry(args, engine.metrics.save_prometheus,
                    engine.metrics.save_json, tracer, profiler)
    print("sample:", [int(t) for t in out["results"][0][:16]])
    if args.parity_check:
        _check_parity(model, params, args, requests, out["results"])
    return out


def _run_router(model, params, args, config, requests):
    """--replicas N: N identical engines (one EngineConfig, shared params)
    behind the prefix-affinity/least-loaded/round-robin router."""
    from repro.serve.engine import ServeEngine
    from repro.serve.router import Router

    tracer, profiler = _telemetry(args)
    # one tracer across the fleet: router rids are globally unique, so
    # every request still gets exactly one track
    engines = [ServeEngine(model, params, config, tracer=tracer,
                           profiler=profiler)
               for _ in range(args.replicas)]
    router = Router(engines, policy=args.route)
    with (profiler if profiler is not None else contextlib.nullcontext()):
        out = router.serve(requests)
    s = out["stats"]
    print(f"router[{args.replicas}x {args.route}]: {s['n_requests']} "
          f"requests ({s['n_prompt']} prompt + {s['n_generated']} new "
          f"tokens) in {s['wall_s']:.2f}s = {s['tok_s']:.1f} tok/s | "
          f"ttft p50/p95 {_ms(s['ttft_p50_s'])}/{_ms(s['ttft_p95_s'])}ms"
          f" | latency p50/p95 {_ms(s['latency_p50_s'])}/"
          f"{_ms(s['latency_p95_s'])}ms | "
          f"{s['n_redispatched']} re-dispatched, "
          f"{s['n_failed_replicas']} failed replicas")
    _print_slo_classes(s)
    for r in s["per_replica"]:
        line = (f"  replica {r['replica']}: {r['n_requests']} requests, "
                f"{r['n_generated']} tokens, {r['n_ticks']} ticks")
        if args.prefix_cache:
            line += f", prefix hit rate {r['prefix_hit_rate']:.1%}"
        if r["failed"]:
            line += " [FAILED]"
        print(line)

    def save_json(path):
        import json
        with open(path, "w") as f:
            json.dump({"router": router.metrics.snapshot(),
                       "replicas": [r.engine.metrics.snapshot()
                                    for r in router.replicas]}, f, indent=1)
            f.write("\n")

    def save_prom(path):
        with open(path, "w") as f:
            f.write(router.to_prometheus())

    _save_telemetry(args, save_prom, save_json, tracer, profiler)
    print("sample:", [int(t) for t in out["results"][0][:16]])
    if args.parity_check:
        _check_parity(model, params, args, requests, out["results"])
    return out


if __name__ == "__main__":
    main()
