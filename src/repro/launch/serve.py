"""Serving launcher: batched generation with optional compressed (BCSR)
weights — the paper's inference path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 32 --sparse
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import pruning
from repro.core.metrics import model_size_bytes
from repro.models.model_zoo import build
from repro.serve.step import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparse", action="store_true",
                    help="magnitude-prune 90%% and report compressed size")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    model = build(args.arch, reduced=args.reduced)
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    if args.sparse:
        params = pruning.magnitude_prune_global(params, 0.9)
        dense_b = model_size_bytes(params, sparse=False)
        sparse_b = model_size_bytes(params, sparse=True)
        print(f"model size dense={dense_b/2**20:.2f}MB "
              f"csr={sparse_b/2**20:.2f}MB ({dense_b/sparse_b:.1f}x)")

    prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                0, cfg.vocab)
    t0 = time.perf_counter()
    out = generate(model, params, prompt, args.gen,
                   temperature=args.temperature,
                   rng=jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
