"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell,
``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()`` must
succeed on the 256-chip single-pod mesh AND the 512-chip 2-pod mesh, and we
extract memory_analysis / cost_analysis / trip-count-corrected HLO costs
(roofline terms) from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single,multi --out experiments/dryrun

Results cache to one JSON per cell; re-runs skip completed cells.
"""
# The VERY FIRST lines — before ANY other import, jax locks device count on
# first init:
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config, shapes_for  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.core.optimizers import prox_adam  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model_zoo import build, input_specs  # noqa: E402
from repro.roofline import analysis  # noqa: E402
from repro.serve.step import make_prefill_step  # noqa: E402
from repro.train.state import TrainState  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

# Layer-stack residual budget. Empirically calibrated on the 104B cell: the
# true per-device footprint is ~5x the bf16 carry-stack estimate (f32
# convert-hoist + transposed copies + attention working set; see
# EXPERIMENTS.md §Perf C-iterations), so the model-estimate budget is set to
# 1.5 GiB to land the real footprint under the 16 GB v5e HBM.
_RESIDUAL_BUDGET = int(0.75 * 1024 ** 3)
_LOSS_SEQ_CHUNK = 512                       # head/loss computed per seq chunk


def _train_microbatches(cfg, shape, chips: int, dp: int,
                        tp: int = 16) -> int:
    """Grad-accumulation depth from the layer-stack activation-residual
    footprint: with remat_policy='nothing' the scan saves one bf16 carry
    per layer, so residual/device = n_layers * B*S*d*2 / (mb*dp). Pick the
    smallest power-of-two mb that fits the budget. HARD CAP: per-microbatch
    batch stays divisible by the data-parallel degree, else activations
    replicate across 'data' (the 197 GB/device baseline failure mode;
    §Perf iteration C1)."""
    # the carry is seq-sharded over TP except for RWKV (exempt from the
    # sequence-parallel residual stream; see models/transformer.py)
    tp_eff = tp if "rwkv" not in cfg.block_pattern else 1
    stack = (cfg.n_layers * shape.global_batch * shape.seq_len
             * cfg.d_model * 2 / dp / tp_eff)
    if cfg.moe is not None:
        # MoE dispatch residuals (top-k routed token copies) dominate the
        # carry for expert models (measured on olmoe; §Perf B-iterations)
        stack *= 1 + min(cfg.moe.top_k, 8)
    mb = 1
    while stack / mb > _RESIDUAL_BUDGET and mb < shape.global_batch:
        mb *= 2
    return max(1, min(mb, shape.global_batch // dp))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               q_chunk: int = 1024, kv_chunk: int = 1024):
    """Build + lower + compile one cell. Returns (compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build(cfg)
    specs = input_specs(cfg, shape)
    rng = jax.random.PRNGKey(0)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single", "chips": chips}

    with shd.use_mesh(mesh):
        if shape.kind == "train":
            opt = prox_adam(1e-4, lam=1e-5)
            dp = (mesh.shape.get("pod", 1)) * mesh.shape["data"]
            mb = _train_microbatches(cfg, shape, chips, dp,
                                     tp=mesh.shape["model"])
            meta["microbatches"] = mb
            step = make_train_step(model, opt, microbatches=mb,
                                   loss_seq_chunk=_LOSS_SEQ_CHUNK)
            state_spec = jax.eval_shape(
                lambda: TrainState.create(model.init(rng), opt))
            state_shd = shd.param_shardings(state_spec, mesh)
            batch_shd = {
                "inputs": shd.activation_sharding(
                    mesh, ("batch", "seq", "embed")[:len(specs["inputs"].shape)],
                    specs["inputs"].shape),
                "labels": shd.activation_sharding(
                    mesh, ("batch", "seq"), specs["labels"].shape),
            }
            jitted = jax.jit(step, in_shardings=(state_shd, batch_shd),
                             out_shardings=(state_shd, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_spec, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            params_spec = jax.eval_shape(model.init, rng)
            params_shd = shd.param_shardings(params_spec, mesh)
            batch_shd = {
                "inputs": shd.activation_sharding(
                    mesh, ("batch", "seq", "embed")[:len(specs["inputs"].shape)],
                    specs["inputs"].shape),
            }
            jitted = jax.jit(step, in_shardings=(params_shd, batch_shd),
                             out_shardings=None)
            lowered = jitted.lower(params_spec,
                                   {"inputs": specs["inputs"]})
        else:  # decode
            params_spec = jax.eval_shape(model.init, rng)
            params_shd = shd.param_shardings(params_spec, mesh)
            cache_spec = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_shd = shd.cache_shardings(cache_spec, mesh)
            tok_shd = shd.activation_sharding(
                mesh, ("batch", "seq", "embed")[:len(specs["inputs"].shape)],
                specs["inputs"].shape)

            def serve_step(params, inputs, cache, pos):
                return model.decode_step(params, inputs, cache, pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(params_shd, tok_shd, cache_shd, None),
                out_shardings=(None, cache_shd),
                donate_argnums=(2,))
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(params_spec, specs["inputs"], cache_spec,
                                   pos_spec)

        compiled = lowered.compile()
    return compiled, cfg, shape, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             force: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(outdir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    try:
        compiled, cfg, shape, meta = lower_cell(arch, shape_name, multi_pod)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        roof = analysis.analyze(compiled.as_text(), cfg, shape,
                                mesh_name, meta["chips"],
                                xla_cost=cost, memory_stats=mem)
        result = {
            "ok": True, "cell": cell_id, **meta,
            "compile_s": time.time() - t0,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_gb": (mem.argument_size_in_bytes
                                       + mem.temp_size_in_bytes
                                       + mem.output_size_in_bytes
                                       - mem.alias_size_in_bytes) / 2**30,
            },
            "roofline": roof.as_dict(),
        }
        try:
            from repro.roofline.flash_adjust import flash_adjusted
            adj = flash_adjusted(result, cfg, shape)
            if adj is not None:
                result["roofline_flash"] = adj
        except Exception as e:  # noqa: BLE001 — adjustment is best-effort
            result["roofline_flash_error"] = f"{type(e).__name__}: {e}"
        print(f"[ok]   {cell_id:56s} compile={result['compile_s']:7.1f}s "
              f"mem/dev={result['memory']['peak_per_device_gb']:6.2f}GB "
              f"dominant={roof.dominant}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result = {"ok": False, "cell": cell_id, "arch": arch,
                  "shape": shape_name, "mesh": mesh_name,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:],
                  "compile_s": time.time() - t0}
        print(f"[FAIL] {cell_id}: {result['error']}")

    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = args.mesh.split(",")
    results = []
    for arch in archs:
        cfg = get_config(arch)
        cell_shapes = shapes_for(cfg) if args.shape == "all" \
            else args.shape.split(",")
        for shape_name in cell_shapes:
            if shape_name == "long_500k" and not cfg.sub_quadratic:
                print(f"[skip] {arch}__long_500k: full attention is "
                      "quadratic at 524k (DESIGN.md §6)")
                continue
            for mesh_name in meshes:
                results.append(run_cell(arch, shape_name,
                                        mesh_name == "multi", args.out,
                                        args.force))
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} cells compiled")
    rows = [r["roofline"] for r in results
            if r.get("ok") and r["mesh"] == "single"]
    if rows:
        print("\nSingle-pod roofline table:\n" + analysis.format_table(rows))


if __name__ == "__main__":
    main()
