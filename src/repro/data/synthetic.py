"""Deterministic synthetic data streams.

Design constraints from the fault-tolerance story (DESIGN.md §5): batches are
a pure function of (seed, step), so a restarted/elastically-rescaled job
replays the exact token stream with no data-loader state to checkpoint.
Each host materializes only its shard of the global batch
(``host_slice``), which is how the pipeline scales to 1000+ nodes.

Token streams use a mixture of Zipf-distributed unigrams and a deterministic
k-gram structure so that a real learning signal exists (loss decreases) —
needed by the paper-reproduction benchmarks. Image streams generate
class-conditional blobs for the CNN experiments (MNIST/CIFAR stand-ins).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 8        # k-gram period giving predictable structure


def token_batch(cfg: TokenStreamConfig, step: int,
                host_start: int = 0, host_size: Optional[int] = None) -> dict:
    """Batch for ``step``; host materializes rows [host_start, +host_size).

    Generation is a pure function of (seed, step) over the GLOBAL batch and
    each host slices its rows, so all hosts agree on the global batch
    content regardless of process count (elastic-restart invariant)."""
    host_size = host_size or cfg.global_batch
    rng = np.random.default_rng((cfg.seed, step))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    # Zipf-ish unigram base
    ranks = rng.integers(1, 1000, size=(b, s + 1))
    base = (v * (ranks.astype(np.float64) ** -1.1)).astype(np.int64) % v
    # overlay deterministic k-gram structure: x[t] depends on x[t-structure]
    k = cfg.structure
    for t in range(k, s + 1):
        mask = (np.arange(b) + t) % 3 == 0
        base[mask, t] = (base[mask, t - k] * 31 + 7) % v
    base = base[host_start:host_start + host_size]
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return {"inputs": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def token_stream(cfg: TokenStreamConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield token_batch(cfg, step)
        step += 1


# ---------------------------------------------------------------------------
# Synthetic image classification (MNIST / CIFAR stand-ins for the paper's
# CNN experiments)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageStreamConfig:
    image_shape: tuple          # (H, W, C)
    n_classes: int
    batch: int
    seed: int = 0
    noise: float = 0.35


_PROTO_CACHE: dict = {}


def _prototypes(cfg: ImageStreamConfig) -> np.ndarray:
    key = (cfg.image_shape, cfg.n_classes, cfg.seed)
    if key not in _PROTO_CACHE:
        rng = np.random.default_rng(cfg.seed + 12345)
        h, w, c = cfg.image_shape
        protos = np.zeros((cfg.n_classes, h, w, c), np.float32)
        yy, xx = np.mgrid[0:h, 0:w]
        for cls in range(cfg.n_classes):
            # class = mixture of 3 gaussian blobs at class-specific spots
            for _ in range(3):
                cy, cx = rng.uniform(0.2, 0.8, 2) * [h, w]
                sig = rng.uniform(0.08, 0.2) * h
                blob = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig ** 2))
                protos[cls] += blob[..., None] * rng.uniform(0.5, 1.0, c)
        _PROTO_CACHE[key] = protos / protos.max()
    return _PROTO_CACHE[key]


def image_batch(cfg: ImageStreamConfig, step: int) -> dict:
    rng = np.random.default_rng((cfg.seed, step))
    labels = rng.integers(0, cfg.n_classes, cfg.batch)
    protos = _prototypes(cfg)
    imgs = protos[labels] + cfg.noise * rng.normal(
        size=(cfg.batch,) + cfg.image_shape).astype(np.float32)
    return {"inputs": jnp.asarray(imgs, jnp.float32),
            "labels": jnp.asarray(labels, jnp.int32)}


MNIST_LIKE = ImageStreamConfig(image_shape=(28, 28, 1), n_classes=10, batch=128)
CIFAR_LIKE = ImageStreamConfig(image_shape=(32, 32, 3), n_classes=10, batch=128)
