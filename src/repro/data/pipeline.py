"""Host data pipeline: per-host sharded batches + background prefetch.

On a real multi-host TPU pod each process feeds its addressable shard of the
global batch (``jax.make_array_from_process_local_data`` pattern). In this
single-process container the pipeline still exercises the same interfaces:
``ShardedBatcher`` computes the host slice from (process_index, host_count)
and ``Prefetcher`` overlaps host batch synthesis with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax

from repro.data.synthetic import TokenStreamConfig, token_batch


class ShardedBatcher:
    """Deterministic per-host batch shards keyed by step (restart-safe)."""

    def __init__(self, cfg: TokenStreamConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.process_index = (jax.process_index() if process_index is None
                              else process_index)
        self.process_count = (jax.process_count() if process_count is None
                              else process_count)
        assert cfg.global_batch % self.process_count == 0
        self.host_size = cfg.global_batch // self.process_count
        self.host_start = self.process_index * self.host_size

    def batch(self, step: int) -> dict:
        return token_batch(self.cfg, step, self.host_start, self.host_size)

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded queue)."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put((step, make_batch(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
