"""Fault-tolerant checkpointing.

Properties required by the 1000+-node story (DESIGN.md §5):

* **Atomic**: writes go to ``step_NNN.tmp-<nonce>`` then ``os.replace`` into
  place — a preempted writer never corrupts the latest checkpoint.
* **Versioned + self-describing**: a manifest (JSON) stores the tree
  structure, shapes, dtypes and the *logical* sharding axes — never device
  layouts — so a checkpoint written on a (16,16) mesh restores onto (2,16,16)
  or any other mesh (**elastic re-shard**: restore is just pjit-placing the
  host arrays with the new mesh's shardings).
* **Compressed sparse storage**: regularized *dense* weight matrices whose
  sparsity exceeds a threshold are stored as elementwise CSR (one-way:
  densified on restore), cutting checkpoint bytes by the paper's compression
  factor — the paper's 'model size' win applied to the training artifact.
  Native **BlockCSR and PaletteBCSR leaves** (e.g. inside a
  ``CompressedParams`` serving tree) round-trip losslessly: their arrays +
  metas are stored verbatim and restore rebuilds the format without
  densifying (quantized stores stay uint8/nibble-packed on disk and at
  load), so a compressed checkpoint restores straight into the
  compressed-model runtime.
* **Retention + resume**: keep_n newest checkpoints; ``latest_step`` scans
  the directory so a restarted job resumes from the newest complete write.

Arrays move through numpy .npz (offline-friendly; no external deps).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.prox import default_regularized_predicate
from repro.sparse.formats import BlockCSR, PaletteBCSR, dense_to_csr

PyTree = Any
_SPARSE_THRESHOLD = 0.7      # store CSR when >= 70% zero

# BlockCSR / PaletteBCSR array fields persisted verbatim for the round-trip
# path (index/gather tables are shared between the two formats)
_INDEX_FIELDS = ("col_idx", "row_ptr",
                 "gather_idx", "gather_blk", "gather_nnz",
                 "gather_t_idx", "gather_t_blk", "gather_t_nnz")
_BCSR_FIELDS = ("data",) + _INDEX_FIELDS
_PBCSR_FIELDS = ("codes", "palette") + _INDEX_FIELDS


def _is_bcsr(x) -> bool:
    return isinstance(x, (BlockCSR, PaletteBCSR))


def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree: PyTree):
    """Flatten with BlockCSR treated as a single (compound) leaf, so
    compressed trees (e.g. ``CompressedParams``) round-trip losslessly."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree,
                                                         is_leaf=_is_bcsr)
    names = ["/".join(_key_name(k) for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3,
                 sparse_storage: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.sparse_storage = sparse_storage
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None):
        names, leaves, _ = _flatten(tree)
        arrays, manifest = {}, {"step": step, "time": time.time(),
                                "extra": extra or {}, "leaves": []}
        for name, leaf in zip(names, leaves):
            if _is_bcsr(leaf):
                # native compressed leaf: store the BCSR/PaletteBCSR arrays
                # verbatim — restore rebuilds the format without densifying
                # (quantized checkpoints stay quantized on disk AND at load)
                quant = isinstance(leaf, PaletteBCSR)
                fields = _PBCSR_FIELDS if quant else _BCSR_FIELDS
                entry = {"name": name,
                         "format": "palette_bcsr" if quant else "bcsr",
                         "shape": list(leaf.shape),
                         "block": list(leaf.block),
                         "n_blocks": int(leaf.n_blocks),
                         "dtype": str(np.asarray(
                             leaf.palette if quant else leaf.data).dtype)}
                if quant:
                    entry["bits"] = int(leaf.bits)
                for f in fields:
                    arrays[f"{name}__{f}"] = np.asarray(
                        jax.device_get(getattr(leaf, f)))
                manifest["leaves"].append(entry)
                continue
            arr = np.asarray(jax.device_get(leaf))
            entry = {"name": name, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "format": "dense"}
            if (self.sparse_storage and arr.ndim == 2
                    and default_regularized_predicate(name, arr)
                    and arr.size > 4096):
                sparsity = 1.0 - np.count_nonzero(arr) / arr.size
                if sparsity >= _SPARSE_THRESHOLD:
                    # storage format is elementwise CSR (the paper's own;
                    # BCSR is the *compute* format — unstructured sparsity
                    # does not compress under MXU-sized blocks)
                    c = dense_to_csr(arr)
                    entry["format"] = "csr"
                    arrays[f"{name}__data"] = np.asarray(c.data)
                    arrays[f"{name}__indices"] = np.asarray(c.indices)
                    arrays[f"{name}__indptr"] = np.asarray(c.indptr)
                    manifest["leaves"].append(entry)
                    continue
            arrays[name] = arr
            manifest["leaves"].append(entry)

        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=self.dir)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k.replace("/", "|"): v for k, v in arrays.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic publish
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.startswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of ``like``; optionally device_put with
        ``shardings`` (a pytree of NamedSharding for the *current* mesh —
        elastic restore onto any mesh)."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        by_name = {e["name"]: e for e in manifest["leaves"]}

        names, leaves, treedef = _flatten(like)
        out = []
        for name, leaf in zip(names, leaves):
            e = by_name[name]
            if e["format"] == "bcsr":
                out.append(_bcsr_restore(npz, name, e))
                continue
            if e["format"] == "palette_bcsr":
                out.append(_pbcsr_restore(npz, name, e))
                continue
            if e["format"] == "csr":
                arr = _csr_restore(npz, name, tuple(e["shape"]),
                                   np.dtype(e["dtype"]))
            else:
                arr = npz[name.replace("/", "|")]
            assert list(arr.shape) == e["shape"], (name, arr.shape, e["shape"])
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:09d}",
                               "manifest.json")) as f:
            return json.load(f)

    def restore_compressed(self, step: Optional[int] = None, mesh=None):
        """Template-free restore of a ``CompressedParams`` checkpoint.

        The manifest's leaf names ("dense/..." / "sparse/...") carry the
        full tree structure and the ``extra['plan']`` entry the
        ``CompressionPlan``, so a server can load a compressed model written
        by ``launch/train --sparse`` without re-deriving a template from the
        architecture (the sparsity pattern lives in the checkpoint, not the
        code). BlockCSR leaves rebuild without densifying.

        ``mesh``: optional ``jax.sharding.Mesh`` — the restored tree is
        device_put with ``distributed.sharding.param_shardings`` (block
        stores row-sharded along the slot axis, index/gather tables and
        palettes replicated). Elastic like the dense restore path: the
        checkpoint stores host arrays, so any mesh shape works.
        """
        from repro.sparse.compress import CompressedParams, CompressionPlan

        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))

        import jax.numpy as jnp
        roots = {"dense": {}, "sparse": {}}
        for e in manifest["leaves"]:
            name = e["name"]
            root, _, rest = name.partition("/")
            if root not in roots or not rest:
                raise ValueError(
                    f"step {step} in {self.dir} is not a CompressedParams "
                    f"checkpoint (leaf {name!r}; was it written by "
                    f"launch/train --sparse?)")
            if e["format"] == "bcsr":
                leaf = _bcsr_restore(npz, name, e)
            elif e["format"] == "palette_bcsr":
                leaf = _pbcsr_restore(npz, name, e)
            elif e["format"] == "csr":
                leaf = jnp.asarray(_csr_restore(npz, name, tuple(e["shape"]),
                                                np.dtype(e["dtype"])))
            else:
                leaf = jnp.asarray(npz[name.replace("/", "|")])
            node = roots[root]
            keys = rest.split("/")
            for k in keys[:-1]:
                node = node.setdefault(k, {})
            node[keys[-1]] = leaf

        spec = (manifest.get("extra") or {}).get("plan")
        plan = CompressionPlan()
        if spec:
            # .get defaults keep checkpoints written before the quantization
            # fields existed loadable
            plan = CompressionPlan(
                block=tuple(spec["block"]),
                min_sparsity=spec["min_sparsity"],
                min_size=spec["min_size"],
                overrides=tuple((s, tuple(b)) for s, b in spec["overrides"]),
                quantize_bits=spec.get("quantize_bits"),
                quantize_overrides=tuple(
                    (s, int(b))
                    for s, b in spec.get("quantize_overrides", ())),
                slot_multiple=spec.get("slot_multiple"))
        cp = CompressedParams(dense=roots["dense"], sparse=roots["sparse"],
                              plan=plan)
        if mesh is not None:
            from repro.distributed.sharding import param_shardings
            cp = jax.device_put(cp, param_shardings(cp, mesh))
        return cp


def _bcsr_restore(npz, name, entry) -> BlockCSR:
    """Rebuild a BlockCSR leaf from its stored arrays — no densification.

    The sparsity pattern (and therefore the array shapes) come from the
    checkpoint, not from the ``like`` template: a compressed checkpoint
    restores bit-exactly even when the template was compressed from
    different weights."""
    import jax.numpy as jnp
    arrs = {f: jnp.asarray(npz[f"{name}__{f}".replace("/", "|")])
            for f in _BCSR_FIELDS}
    return BlockCSR(shape=tuple(entry["shape"]), block=tuple(entry["block"]),
                    n_blocks=int(entry["n_blocks"]), **arrs)


def _pbcsr_restore(npz, name, entry) -> PaletteBCSR:
    """Rebuild a PaletteBCSR leaf from its stored arrays — codes stay
    quantized (and nibble-packed at 4 bits) from disk into serving memory."""
    import jax.numpy as jnp
    arrs = {f: jnp.asarray(npz[f"{name}__{f}".replace("/", "|")])
            for f in _PBCSR_FIELDS}
    return PaletteBCSR(shape=tuple(entry["shape"]),
                       block=tuple(entry["block"]),
                       n_blocks=int(entry["n_blocks"]),
                       bits=int(entry["bits"]), **arrs)


def _csr_restore(npz, name, shape, dtype):
    data = npz[f"{name}__data".replace("/", "|")]
    indices = npz[f"{name}__indices".replace("/", "|")]
    indptr = npz[f"{name}__indptr".replace("/", "|")]
    dense = np.zeros(shape, dtype)
    rows = np.repeat(np.arange(shape[0]), indptr[1:] - indptr[:-1])
    dense[rows, indices] = data
    return dense
