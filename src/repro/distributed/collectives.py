"""Distributed-optimization extras: error-feedback gradient compression.

int8 quantized all-reduce with error feedback (1-bit-Adam / PowerSGD family,
simplified): gradients are scaled per-leaf to int8 before the data-parallel
reduction and the quantization residual is carried to the next step, so the
compression error is compensated rather than accumulated. Under GSPMD the
"all-reduce" is implicit (psum of sharded grads); we expose an explicit
shard_map variant for meshes where the data-parallel reduction dominates the
collective roofline term (see EXPERIMENTS.md §Perf napkin math: 4x fewer
bytes on the 'data' axis at <1e-2 relative grad error).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: PyTree, error: Optional[PyTree]
                      ) -> tuple[PyTree, PyTree]:
    """Error-feedback int8 compression of a gradient pytree.

    Returns (decompressed_grads, new_error). The *decompressed* values are
    what enters the all-reduce under GSPMD; on a real pod the int8 payload is
    what crosses ICI (4x fewer bytes than fp32, 2x fewer than bf16).
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_e = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return new_g, new_e


def psum_compressed(grads: PyTree, axis_name: str) -> PyTree:
    """shard_map-side compressed reduction: quantize -> psum(int32) -> deq.

    Used inside shard_map bodies where the data-parallel all-reduce is
    explicit; int8 payloads are accumulated in int32 to avoid overflow, then
    rescaled by the max participating scale.
    """
    def one(g):
        q, s = quantize_int8(g)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(s, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (acc.astype(jnp.float32) * smax / n).astype(g.dtype)

    return jax.tree.map(one, grads)
