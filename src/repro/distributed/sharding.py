"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates activations with *logical* axis names via
``shard_ann(x, axes)``; params get logical axes from path-based rules
(``param_logical_axes``). A mesh context (``use_mesh``) maps logical axes to
physical mesh axes with divisibility checks — an axis that doesn't divide is
silently replicated, which is what makes e.g. MQA (kv=1) work on a model=16
mesh while GQA (kv=16) shards.

Physical mesh axes: ("pod", "data", "model").
  batch            -> ("pod", "data")      data parallelism across pods
  heads/kv/mlp/
  vocab/experts/lru-> "model"              tensor / expert parallelism
  embed (params)   -> "data"               FSDP (ZeRO-3) weight sharding
  cache_seq        -> "model"              sequence-parallel decode
  capacity         -> "data"               MoE buffer sharding
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sparse.formats import BlockCSR, PaletteBCSR

_ctx = threading.local()

ACT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "capacity": "data",
    "cache_seq": "model",
    "lru": "model",
    "rwkv_heads": "model",
    "conv": None,
    # sequence-parallel residual stream (Megatron-SP style): the scan carry
    # between layers is seq-sharded over 'model', shrinking the per-layer
    # bwd residual stack by the TP degree (§Perf iteration C3). Norms/MLP/
    # projections are per-token so this adds no collectives there; XLA
    # re-shards at attention (KV gather) where cross-token work happens.
    "res_seq": "model",
    # FALLBACK sequence sharding for attention internals: claims 'model'
    # only when no primary axis (heads/kv) could — e.g. smollm's 15 heads
    # on a 16-way axis replicate attention 16x without it (§Perf A1).
    "seq_fb": "model",
}

_FALLBACK_AXES = {"seq_fb"}

PARAM_RULES: dict[str, Any] = {
    "layers": None,
    "embed": "data",          # FSDP axis for weight matrices
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "lru": "model",
    "rwkv_heads": "model",
    "lora": None,
    "conv": None,
    "none": None,
}


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], act_rules: Optional[dict] = None):
    """Context under which shard_ann applies with_sharding_constraint."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, act_rules or ACT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def _axes_to_spec(logical: Sequence[Optional[str]], shape, mesh: Mesh,
                  rules: dict) -> P:
    """Two-pass assignment: primary logical axes claim mesh axes first;
    fallback axes (seq_fb) only take what remains unclaimed."""
    taken: set[str] = set()
    spec: list = [None] * len(logical)

    def assign(i, dim, ax):
        phys = rules.get(ax) if ax else None
        if phys is None:
            return
        phys_t = (phys,) if isinstance(phys, str) else tuple(phys)
        phys_t = tuple(a for a in phys_t if a in mesh.shape and a not in taken)
        size = 1
        for a in phys_t:
            size *= mesh.shape[a]
        if phys_t and dim % size == 0 and dim > 0:
            spec[i] = phys_t if len(phys_t) > 1 else phys_t[0]
            taken.update(phys_t)

    for i, (dim, ax) in enumerate(zip(shape, logical)):
        if ax not in _FALLBACK_AXES:
            assign(i, dim, ax)
    for i, (dim, ax) in enumerate(zip(shape, logical)):
        if ax in _FALLBACK_AXES:
            assign(i, dim, ax)
    return P(*spec)


def shard_ann(x, logical: Sequence[Optional[str]]):
    """Annotate an activation with a sharding constraint (no-op w/o mesh)."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    if x.ndim != len(logical):
        return x
    spec = _axes_to_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param logical axes by path pattern
# ---------------------------------------------------------------------------
# Matched in order against jax.tree_util.keystr paths; first hit wins.
# Leading "layers" axis is added automatically for scan-stacked leaves.

_PARAM_PATTERNS: list[tuple[str, tuple]] = [
    (r"embedding",                ("vocab", "embed")),
    (r"head",                     ("embed", "vocab")),
    (r"\bwq\b|'wq'",              ("embed", "heads", "head_dim")),
    (r"'wk'|'wv'",                ("embed", "kv_heads", "head_dim")),
    (r"'wo'",                     ("heads", "head_dim", "embed")),
    (r"'wi'|'wg'",                ("embed", "mlp")),
    (r"'w_down'",                 ("mlp", "embed")),
    (r"router",                   ("embed", "experts")),
    (r"experts.*'wi'|'ewi'|'ewg'", ("experts", "embed", "mlp")),
    (r"'ewo'",                    ("experts", "mlp", "embed")),
    (r"conv1d",                   ("conv", "lru")),
    (r"lru_in|lru_gate",          ("embed", "lru")),
    (r"lru_out",                  ("lru", "embed")),
    (r"rwkv_(r|k|v|g)",           ("embed", "embed2")),
    (r"rwkv_o",                   ("embed2", "embed")),
    (r"cm_(k)",                   ("embed", "mlp")),
    (r"cm_(v)",                   ("mlp", "embed")),
    (r"cm_(r)",                   ("embed", "embed2")),
    (r"lora_(a|b)",               ("lora", "lora")),
]

# 'embed2' lets square (d, d) matrices shard their *output* dim over model
PARAM_RULES["embed2"] = "model"


def _leaf_axes(path: str, leaf) -> tuple:
    for pat, axes in _PARAM_PATTERNS:
        if re.search(pat, path):
            if len(axes) == leaf.ndim:
                return axes
            if len(axes) == leaf.ndim - 1:
                return ("layers",) + axes      # scan-stacked
    # vectors / scalars / unknowns: replicate
    return tuple([None] * leaf.ndim)


def param_logical_axes(params) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    axes = [_leaf_axes(jax.tree_util.keystr(p), l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, axes)


# ---------------------------------------------------------------------------
# Compressed (BlockCSR / PaletteBCSR) leaves
# ---------------------------------------------------------------------------
# A compressed projection shards like its dense counterpart's OUTPUT dim:
# the BCSR slot axis stores nonzero blocks row-major over block rows of the
# (out, in) view, so splitting slots across devices splits block rows —
# the compressed analogue of sharding the dense out dimension. Everything
# that describes the sparsity pattern (col_idx/row_ptr/gather tables) and
# the palette is replicated: the tables are tiny, every device needs the
# full pattern to interpret its slots, and replication keeps the scalar-
# prefetch index maps host-local.
#
# Leaf-name -> logical axis of the (out, in) row dim (mapped to a physical
# mesh axis through PARAM_RULES, with the usual divisibility fallback to
# replication). Matched against keystr paths AND split_trainable's
# "bcsr_data/<path>" keys, so shardings survive the SpC-Retrain debias
# phase unchanged.

_BCSR_ROW_PATTERNS: list[tuple[str, str]] = [
    (r"\bwq\b|\bwk\b|\bwv\b",                         "heads"),
    (r"\bewi\b|\bewg\b|\bwi\b|\bwg\b|\bcm_k\b",       "mlp"),
    (r"\bewo\b|\bwo\b|\bcm_v\b|\brwkv_o\b|\blru_out\b", "embed"),
    (r"\brwkv_[rkvg]\b|\bcm_r\b",                     "embed2"),
    (r"\blru_in\b|\blru_gate\b",                      "lru"),
    (r"\bhead\b",                                     "vocab"),
]

_BCSR_META = ("shape", "block", "n_blocks", "bits")


def _is_bcsr(x) -> bool:
    return isinstance(x, (BlockCSR, PaletteBCSR))


def _bcsr_row_spec(path: str, arr, mesh: Mesh, rules: dict) -> P:
    """Spec for a BCSR block store (lead..., n_slots, br, bc): slot axis
    sharded by the dense out-dim rule, lead (layer/expert) axes replicated."""
    logical = None
    for pat, ax in _BCSR_ROW_PATTERNS:
        if re.search(pat, path):
            logical = ax
            break
    axes: list = [None] * arr.ndim
    slot_axis = arr.ndim - 3
    if slot_axis >= 0:
        axes[slot_axis] = logical
    return _axes_to_spec(axes, arr.shape, mesh, rules)


def _bcsr_leaf_shardings(path: str, leaf, mesh: Mesh, rules: dict):
    """Mirror a BlockCSR/PaletteBCSR with NamedShardings per array field:
    data/codes row-sharded, indices + gather tables + palette replicated."""
    repl = NamedSharding(mesh, P())
    fields = {f.name: repl for f in dataclasses.fields(leaf)
              if f.name not in _BCSR_META}
    store = "codes" if isinstance(leaf, PaletteBCSR) else "data"
    fields[store] = NamedSharding(
        mesh, _bcsr_row_spec(path, getattr(leaf, store), mesh, rules))
    return dataclasses.replace(leaf, **fields)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    """NamedSharding pytree for params (or any state with param-like paths).

    Handles compressed pytrees (``CompressedParams`` / trees holding
    ``BlockCSR``/``PaletteBCSR`` leaves, and ``split_trainable``'s
    ``bcsr_data`` view): index/palette arrays replicate, block stores shard
    along the slot (block-row) axis per the dense rule for that path."""
    rules = rules or PARAM_RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(params,
                                                         is_leaf=_is_bcsr)
    out = []
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        if _is_bcsr(leaf):
            out.append(_bcsr_leaf_shardings(ps, leaf, mesh, rules))
            continue
        if "bcsr_data" in ps:               # split_trainable's data view
            out.append(NamedSharding(mesh,
                                     _bcsr_row_spec(ps, leaf, mesh, rules)))
            continue
        axes = _leaf_axes(ps, leaf)
        spec = _axes_to_spec(axes, leaf.shape, mesh, rules)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def activation_sharding(mesh: Mesh, logical: Sequence[Optional[str]], shape):
    return NamedSharding(mesh, _axes_to_spec(logical, shape, mesh, ACT_RULES))


# ---------------------------------------------------------------------------
# Decode-cache logical axes by path pattern
# ---------------------------------------------------------------------------

_CACHE_PATTERNS: list[tuple[str, tuple]] = [
    (r"'k_scale'|'v_scale'",
     ("batch", "cache_seq", "kv_heads", "head_dim")),
    (r"'k'|'v'",   ("batch", "cache_seq", "kv_heads", "head_dim")),
    (r"'S'",       ("batch", "rwkv_heads", "head_dim", "head_dim2")),
    (r"'shift'",   ("batch", "embed")),
    (r"'h'",       ("batch", "lru")),
    (r"'conv'",    ("batch", "conv", "lru")),
]


def _cache_leaf_axes(path: str, leaf) -> tuple:
    for pat, axes in _CACHE_PATTERNS:
        if re.search(pat, path):
            if len(axes) == leaf.ndim:
                return axes
            if len(axes) == leaf.ndim - 1:
                return ("layers",) + axes       # scan-stacked
    return tuple([None] * leaf.ndim)


def cache_shardings(cache, mesh: Mesh):
    rules = dict(ACT_RULES)
    rules.update({"layers": None, "head_dim2": None})
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        axes = _cache_leaf_axes(jax.tree_util.keystr(path), leaf)
        out.append(NamedSharding(mesh, _axes_to_spec(axes, leaf.shape, mesh,
                                                     rules)))
    return jax.tree_util.tree_unflatten(treedef, out)
