"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints store host arrays + logical axes (never device layouts), so
re-scaling a job is: build the new mesh -> derive NamedShardings from the
same logical rules -> device_put at restore. This module packages that and
validates divisibility (an axis that no longer divides falls back to
replication, identically to sharding.py's constraint logic — the job *runs*,
just with less parallelism on that tensor).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.sharding import param_shardings

PyTree = Any


def restore_onto_mesh(ckpt: Checkpointer, step: int, like: PyTree,
                      mesh: Mesh) -> PyTree:
    """Elastic restore: place checkpoint arrays for the given mesh."""
    shardings = param_shardings(like, mesh)
    return ckpt.restore(step, like, shardings=shardings)


def rescale_plan(old_mesh_shape: dict, new_mesh_shape: dict,
                 global_batch: int) -> dict:
    """Operator-facing summary of what changes when re-meshing.

    Data parallel degree change rescales per-host batch; model-parallel
    change re-partitions weights (free at restore); a shrink that breaks
    divisibility is reported so the operator can adjust global batch.
    """
    def dp(shape):
        return shape.get("pod", 1) * shape.get("data", 1)

    old_dp, new_dp = dp(old_mesh_shape), dp(new_mesh_shape)
    plan = {
        "old_dp": old_dp, "new_dp": new_dp,
        "old_tp": old_mesh_shape.get("model", 1),
        "new_tp": new_mesh_shape.get("model", 1),
        "batch_divisible": global_batch % new_dp == 0,
        "per_replica_batch": global_batch // new_dp
        if global_batch % new_dp == 0 else None,
    }
    return plan
