"""train_step / grad-accumulated train_step factories.

The step is a pure function (state, batch) -> (state, metrics) suitable for
jax.jit with in/out shardings from distributed/sharding.py. Compression is
first-class: the optimizer IS a prox optimizer, so every step ends with the
paper's soft-thresholding (or runs debiased with a frozen mask).

Microbatching (gradient accumulation) splits the batch on the leading axis
and lax.scan's over microbatches — used when the per-device activation
footprint of the full global batch exceeds HBM.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.optimizers import ProxOptimizer
from repro.train.losses import next_token_loss
from repro.train.state import TrainState

PyTree = Any


def make_loss_fn(model, aux_weight: float = 1e-2,
                 loss_seq_chunk: int = 0,
                 param_transform: Optional[Callable] = None) -> Callable:
    """loss_seq_chunk > 0: compute head+loss in sequence chunks so the
    (B, S, vocab) logits tensor is never materialized (decisive for the
    256k-vocab archs — see EXPERIMENTS.md §Perf iteration C1). Each chunk is
    rematted so backward recomputes its logits instead of saving them.

    ``param_transform`` maps the *trainable* pytree to what the model
    consumes before the forward (pure restructuring; grads flow through).
    Used by SpC-Retrain's debias phase, where the trainable tree is
    ``sparse.compress.split_trainable``'s {dense residue, BlockCSR.data}
    view and the transform rebuilds the ``CompressedParams``."""

    def loss_fn(params, batch):
        if param_transform is not None:
            params = param_transform(params)
        if not loss_seq_chunk or batch["labels"].shape[1] <= loss_seq_chunk:
            logits, aux = model.apply_train(params, batch)
            loss = next_token_loss(logits, batch["labels"])
        else:
            hidden, aux = model.apply_hidden(params, batch)
            b, s = batch["labels"].shape
            n = s // loss_seq_chunk
            assert s % loss_seq_chunk == 0, (s, loss_seq_chunk)
            hc = hidden.reshape(b, n, loss_seq_chunk, -1).transpose(1, 0, 2, 3)
            lc = batch["labels"].reshape(b, n, loss_seq_chunk).transpose(1, 0, 2)

            def chunk_loss(carry, xs):
                h, l = xs
                logits = model.head(params, h)
                return carry + next_token_loss(logits, l), None

            body = jax.checkpoint(
                chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
            loss = total / n
        loss = loss + aux_weight * aux["load_balance"] + aux["z_loss"]
        return loss, {"loss": loss, "load_balance": aux["load_balance"]}

    return loss_fn


def make_train_step(model, opt: ProxOptimizer,
                    microbatches: int = 1,
                    aux_weight: float = 1e-2,
                    loss_seq_chunk: int = 0,
                    param_transform: Optional[Callable] = None) -> Callable:
    loss_fn = make_loss_fn(model, aux_weight, loss_seq_chunk=loss_seq_chunk,
                           param_transform=param_transform)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    cdt = {"bfloat16": jnp.bfloat16, "float16": jnp.float16,
           "float32": jnp.float32}[model.cfg.compute_dtype] \
        if hasattr(model, "cfg") else jnp.float32

    def cast_compute(params):
        """Mixed precision: one hoisted cast of the master fp32 params to
        the compute dtype, so every FSDP weight all-gather inside the
        microbatch/layer loops moves bf16, not fp32 (§Perf iteration C4).
        Grads w.r.t. the cast copy apply to the fp32 master unchanged."""
        return jax.tree.map(
            lambda p: p.astype(cdt)
            if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)

    def compute_grads(params, batch):
        params = cast_compute(params)
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, metrics = jax.lax.scan(body, zeros, split)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        grads, metrics = compute_grads(state.params, batch)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params,
                                         mask=state.mask)
        grad_norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, grad_norm=grad_norm)
        return TrainState(params=new_params, opt_state=new_opt,
                          mask=state.mask, step=state.step + 1), metrics

    return train_step


def make_eval_step(model) -> Callable:
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
