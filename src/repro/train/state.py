"""TrainState pytree: params + prox-optimizer state + debias mask."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.optimizers import ProxOptimizer, ProxState

PyTree = Any


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt_state", "mask", "step"],
         meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: ProxState
    mask: Optional[PyTree]      # debias mask (None until retraining phase)
    step: jax.Array

    @classmethod
    def create(cls, params: PyTree, opt: ProxOptimizer,
               mask: Optional[PyTree] = None) -> "TrainState":
        return cls(params=params, opt_state=opt.init(params), mask=mask,
                   step=jnp.zeros((), jnp.int32))
