"""Fault-tolerant training loop + the paper's SpC -> debias pipeline.

Responsibilities:
  * checkpoint/restart: resumes from the newest complete checkpoint; data is
    re-derived from (seed, step) so replay is exact (no loader state),
  * preemption safety: checkpoints are atomic (checkpoint/checkpointer.py)
    and written every ``ckpt_every`` steps + at exit,
  * straggler/failure model: SPMD training is synchronous — a lost host is
    handled by restart-from-checkpoint, optionally onto a *smaller or larger
    mesh* (elastic re-shard at restore). A watchdog records step wall-times
    and flags stragglers (> k*median) for the operator,
  * compression pipeline: ``run_spc_pipeline`` = sparse-coding training then
    mask-frozen debias retraining (paper §2.4), each phase resumable.

SpC-Retrain (``run_spc_retrain_pipeline``) — the fully compressed variant of
the paper's pipeline, where training produces block sparsity directly and
retraining runs *on the compressed representation*:

    SpC training                    compress                 debias retrain
    ┌─────────────────────┐   ┌───────────────────┐   ┌─────────────────────┐
    │ prox-opt, group-l1  │   │ compress_params   │   │ masks frozen to the │
    │ on the plan's       │──▶│ (NO prune step:   │──▶│ CompressedParams:   │
    │ (out, in) BCSR grid │   │ zeros came from   │   │ only BlockCSR.data  │
    │ → exact zero blocks │   │ training)         │   │ updates, dw via     │
    └─────────────────────┘   └───────────────────┘   │ SDDMM at resident   │
                                                      │ slots only          │
                                                      └──────────┬──────────┘
                                                                 ▼
                                          compressed checkpoint, servable by
                                          ``launch/serve --sparse`` (BCSR)
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import masks as masks_lib
from repro.core import metrics as metrics_lib
from repro.core.optimizers import ProxOptimizer
from repro.distributed import sharding as shd
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   compressed_size_bytes, split_trainable)
from repro.train.state import TrainState

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 200
    log_every: int = 20
    straggler_factor: float = 3.0


class StragglerWatchdog:
    """Flags abnormally slow steps (operator signal; sync SPMD can't skip)."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.times: list[float] = []
        self.factor = factor
        self.window = window
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float):
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 10:
            med = float(np.median(hist))
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, dt, med)


def train_loop(train_step: Callable,
               state: TrainState,
               batch_fn: Callable[[int], dict],
               loop_cfg: LoopConfig,
               checkpointer: Optional[Checkpointer] = None,
               metrics_cb: Optional[Callable[[int, dict], None]] = None,
               telemetry=None, phase: str = "train",
               extra_fn: Optional[Callable] = None):
    """Run (and resume) one training phase. Returns (state, history).

    ``telemetry`` (an ``obs.TrainTelemetry``) gets one phase-tagged JSONL
    record per log step; ``extra_fn(params) -> dict`` augments it with
    host-side measurements (e.g. ``obs.sparsity_telemetry_fn`` — live
    block sparsity + group-l1 penalty on the serving grid)."""
    start = int(state.step)
    if checkpointer is not None:
        latest = checkpointer.latest_step()
        if latest is not None and latest > start:
            log.info("resuming from checkpoint step %d", latest)
            state = checkpointer.restore(latest, state)
            start = int(state.step)

    watchdog = StragglerWatchdog(loop_cfg.straggler_factor)
    history: list[dict] = []

    for step in range(start, loop_cfg.total_steps):
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            history.append(metrics)
            if metrics_cb:
                metrics_cb(step, metrics)
            if telemetry is not None:
                rec = {"phase": phase, **metrics}
                if extra_fn is not None:
                    rec.update(extra_fn(state.params))
                telemetry.emit(rec)
        watchdog.record(step, time.perf_counter() - t0)

        if checkpointer is not None and (step + 1) % loop_cfg.ckpt_every == 0:
            checkpointer.save(int(state.step), state)

    if checkpointer is not None:
        checkpointer.save(int(state.step), state)
    return state, history


def run_spc_pipeline(params,
                     make_train_step: Callable[[ProxOptimizer], Callable],
                     opt_spc: ProxOptimizer,
                     opt_debias: ProxOptimizer,
                     batch_fn: Callable[[int], dict],
                     spc_steps: int,
                     debias_steps: int = 0,
                     checkpointer: Optional[Checkpointer] = None,
                     log_every: int = 50,
                     telemetry=None,
                     sparsity_block: Optional[tuple] = None):
    """The paper's full pipeline (§2): SpC training, then debias retraining
    with the zero mask frozen and regularization off. Returns
    (final_state, spc_history, debias_history, compression_report).

    ``telemetry``/``sparsity_block``: stream phase-tagged JSONL records
    (loss, grad norm, and — during SpC, when a block grid is given — live
    block sparsity + group-l1 penalty) via ``obs.TrainTelemetry``."""
    extra_fn = None
    if telemetry is not None and sparsity_block is not None:
        from repro.obs.profile import sparsity_telemetry_fn
        extra_fn = sparsity_telemetry_fn(tuple(sparsity_block))
    step_spc = make_train_step(opt_spc)
    state = TrainState.create(params, opt_spc)
    cfg = LoopConfig(total_steps=spc_steps, log_every=log_every)
    state, hist_spc = train_loop(step_spc, state, batch_fn, cfg, checkpointer,
                                 telemetry=telemetry, phase="spc",
                                 extra_fn=extra_fn)
    report = {"spc": metrics_lib.total_compression(state.params)}

    hist_db: list[dict] = []
    if debias_steps:
        mask = masks_lib.zero_mask(state.params)
        state = TrainState(params=state.params,
                           opt_state=opt_debias.init(state.params),
                           mask=mask, step=jnp.zeros((), jnp.int32))
        step_db = make_train_step(opt_debias)
        cfg = LoopConfig(total_steps=debias_steps, log_every=log_every)
        state, hist_db = train_loop(step_db, state, batch_fn, cfg, None,
                                    telemetry=telemetry, phase="debias",
                                    extra_fn=extra_fn)
        report["debias"] = metrics_lib.total_compression(state.params)
    if telemetry is not None:
        telemetry.emit({"phase": "report", **report})
    return state, hist_spc, hist_db, report


def run_spc_retrain_pipeline(params,
                             make_train_step: Callable,
                             opt_spc: ProxOptimizer,
                             opt_debias: ProxOptimizer,
                             batch_fn: Callable[[int], dict],
                             spc_steps: int,
                             debias_steps: int,
                             plan: CompressionPlan,
                             checkpointer: Optional[Checkpointer] = None,
                             log_every: int = 50,
                             telemetry=None):
    """SpC -> compress -> mask-frozen debias ON the compressed params.

    ``opt_spc`` should carry the plan-aligned group-l1 prox
    (``sparse.compress.make_plan_prox(plan)``) so whole (out, in) blocks hit
    exact zero during training — compression then needs no prune step. The
    debias phase retrains *from* the compressed model: the trainable tree is
    ``split_trainable``'s {dense residue, BlockCSR.data} view, masks are
    frozen to the compressed zero pattern, and the weight gradient reaches
    BlockCSR.data through ``sparse_matmul``'s SDDMM backward (resident
    slots only, never densified).

    ``make_train_step(opt, param_transform=None)`` must forward the
    transform to ``train.step.make_train_step``. Returns
    (compressed_params, hist_spc, hist_db, report).
    """
    extra_fn = None
    if telemetry is not None:
        # live sparsity on the plan's exact serving grid — the SpC
        # trajectory records report the zero-block fraction the
        # compression step below will actually realize
        from repro.obs.profile import sparsity_telemetry_fn
        extra_fn = sparsity_telemetry_fn(tuple(plan.block))
    step_spc = make_train_step(opt_spc)
    state = TrainState.create(params, opt_spc)
    cfg = LoopConfig(total_steps=spc_steps, log_every=log_every)
    state, hist_spc = train_loop(step_spc, state, batch_fn, cfg, checkpointer,
                                 telemetry=telemetry, phase="spc",
                                 extra_fn=extra_fn)
    report = {"spc": metrics_lib.total_compression(state.params)}

    cp = compress_params(state.params, plan)
    mesh = shd.current_mesh()
    if mesh is not None:
        # compress_params builds the BCSR structures host-side; under the
        # production mesh re-place the compressed pytree so block stores are
        # row-sharded and index tables replicated. split_trainable reuses
        # these arrays, so the debias phase trains sharded without any
        # further placement.
        cp = jax.device_put(cp, shd.param_shardings(cp, mesh))
    dense_bytes = sum(int(l.size) * l.dtype.itemsize
                      for l in jax.tree.leaves(state.params))
    report["bcsr_bytes"] = compressed_size_bytes(cp)
    report["dense_bytes"] = dense_bytes
    if telemetry is not None:
        telemetry.emit({"phase": "compress",
                        "bcsr_bytes": report["bcsr_bytes"],
                        "dense_bytes": report["dense_bytes"]})

    hist_db: list[dict] = []
    if debias_steps:
        trainable, rebuild = split_trainable(cp)
        mask = masks_lib.zero_mask(trainable)
        st = TrainState(params=trainable,
                        opt_state=opt_debias.init(trainable),
                        mask=mask, step=jnp.zeros((), jnp.int32))
        step_db = make_train_step(opt_debias, param_transform=rebuild)
        cfg = LoopConfig(total_steps=debias_steps, log_every=log_every)
        # debias trains the compressed representation itself (BlockCSR
        # data slots) — the dense-grid sparsity probe does not apply, the
        # mask is frozen anyway; records carry the plain loss metrics
        st, hist_db = train_loop(step_db, st, batch_fn, cfg, None,
                                 telemetry=telemetry, phase="debias")
        cp = rebuild(st.params)
    return cp, hist_spc, hist_db, report
