"""Training losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits, labels, z_weight: float = 1e-4):
    """Causal LM loss: predict labels[t] from logits[t] (labels are already
    shifted by the data pipeline). Adds a small logit z-loss for stability."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return jnp.mean(nll) + z_weight * z


def softmax_xent(logits, labels):
    """Classification loss for the paper's CNN experiments."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
