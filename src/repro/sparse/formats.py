"""Compressed sparse weight formats (paper §3.1, adapted for TPU).

The paper stores sparse weights in CSR for OpenCL kernels. On TPU the MXU
wants >= (8, 128) tiles, so the framework's first-class format is **BlockCSR
(BCSR)**: the matrix is tiled into (br, bc) blocks and only nonzero blocks are
stored. Alongside the classic (data, col_idx, row_ptr) arrays we precompute
*padded gather tables* — per output block-row, a fixed-width list of
(block-col index, data-slot index) — which are what the Pallas kernel's
scalar-prefetch index maps consume. A transposed gather table (block-CSC
view) serves the backward dense x compressed product without materializing
W^T (DESIGN.md §2: the paper pays uncoalesced access; we pay a one-time host
index sort).

A plain elementwise CSR is retained (``CSR``) as the paper-fidelity format
for size accounting and the embedded/serial reference path.

**PaletteBCSR** is the Deep-Compression stage-2 serving format (Han et al.
2016, the paper's cited follow-up): the BCSR block store holds uint8 palette
*codes* (packed two-per-byte at 4 bits) plus a per-layer fp32 palette of
2**bits values; code 0 is reserved for exact zero so intra-block sparsity
survives quantization bit-exactly. Index/gather tables are shared with
BlockCSR, so a PaletteBCSR drops into every consumer of the gather tables
(the Pallas kernel dequantizes resident blocks on the fly — palette lookup
fused into the gather-block-matmul).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "col_idx", "row_ptr",
                      "gather_idx", "gather_blk", "gather_nnz",
                      "gather_t_idx", "gather_t_blk", "gather_t_nnz"],
         meta_fields=["shape", "block", "n_blocks"])
@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """Block-CSR sparse matrix of logical ``shape`` with (br, bc) blocks.

    data:      (n_slots, br, bc) nonzero blocks, row-major over block rows.
               Slot 0 is always an all-zero pad block; real blocks start at 1,
               so padded gather entries can point at slot 0 harmlessly.
    col_idx:   (n_slots,) int32 block-column of each slot (0 for the pad).
    row_ptr:   (R+1,) int32 CSR pointers into slots 1..n_blocks.
    gather_*:  (R, Jmax) padded per-block-row tables driving the forward
               kernel; gather_nnz (R,) gives the valid prefix length.
    gather_t_*: the block-CSC (transposed) tables, (C, Jmax_t), for backward.
    """
    data: Array
    col_idx: Array
    row_ptr: Array
    gather_idx: Array
    gather_blk: Array
    gather_nnz: Array
    gather_t_idx: Array
    gather_t_blk: Array
    gather_t_nnz: Array
    shape: tuple[int, int]
    block: tuple[int, int]
    n_blocks: int

    @property
    def block_grid(self) -> tuple[int, int]:
        br, bc = self.block
        return (-(-self.shape[0] // br), -(-self.shape[1] // bc))

    @property
    def nnz(self) -> int:
        return self.n_blocks * self.block[0] * self.block[1]

    @property
    def nbytes(self) -> int:
        return sum(int(x.size) * x.dtype.itemsize
                   for x in (self.data, self.col_idx, self.row_ptr))

    def to_dense(self) -> Array:
        return bcsr_to_dense(self)


def dense_to_bcsr(w, block: tuple[int, int] = (128, 128),
                  pad_rows_to_multiple: bool = True) -> BlockCSR:
    """Convert a dense 2D array to BlockCSR, keeping blocks with any nonzero.

    Host-side (numpy): format construction happens at checkpoint-load /
    compression time, never inside a jitted step.
    """
    w = np.asarray(w)
    assert w.ndim == 2, w.shape
    br, bc = block
    r, c = w.shape
    pr, pc = (-r) % br, (-c) % bc
    if (pr or pc):
        if not pad_rows_to_multiple:
            raise ValueError(f"shape {w.shape} not divisible by block {block}")
        w = np.pad(w, ((0, pr), (0, pc)))
    R, C = w.shape[0] // br, w.shape[1] // bc
    wb = w.reshape(R, br, C, bc).transpose(0, 2, 1, 3)  # (R, C, br, bc)
    nz = np.any(wb != 0, axis=(2, 3))                   # (R, C) block occupancy

    rows, cols = np.nonzero(nz)                         # row-major order
    n_blocks = len(rows)
    data = np.zeros((n_blocks + 1, br, bc), dtype=w.dtype)
    data[1:] = wb[rows, cols]
    col_idx = np.zeros(n_blocks + 1, dtype=np.int32)
    col_idx[1:] = cols
    row_ptr = np.zeros(R + 1, dtype=np.int32)
    np.add.at(row_ptr, rows + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)

    # forward gather tables (per block-row)
    jmax = max(int(np.max(row_ptr[1:] - row_ptr[:-1])), 1) if R else 1
    g_idx = np.zeros((R, jmax), np.int32)
    g_blk = np.zeros((R, jmax), np.int32)
    gn = np.zeros(R, np.int32)
    for rr in range(R):
        lo, hi = row_ptr[rr], row_ptr[rr + 1]
        g_idx[rr, :hi - lo] = cols[lo:hi]
        g_blk[rr, :hi - lo] = np.arange(lo + 1, hi + 1)  # +1: slot 0 is the pad
        gn[rr] = hi - lo
    g_nnz = gn

    # transposed (block-CSC) gather tables (per block-col)
    order = np.lexsort((rows, cols))
    t_rows, t_cols, t_slots = rows[order], cols[order], order + 1
    tn = np.zeros(C, np.int32)
    np.add.at(tn, t_cols, 1)
    jmax_t = max(int(tn.max()) if C else 1, 1)
    t_idx = np.zeros((C, jmax_t), np.int32)
    t_blk = np.zeros((C, jmax_t), np.int32)
    fill = np.zeros(C, np.int32)
    for rr, cc, ss in zip(t_rows, t_cols, t_slots):
        t_idx[cc, fill[cc]] = rr
        t_blk[cc, fill[cc]] = ss
        fill[cc] += 1

    dev = jnp.asarray
    return BlockCSR(
        data=dev(data), col_idx=dev(col_idx), row_ptr=dev(row_ptr),
        gather_idx=dev(g_idx), gather_blk=dev(g_blk), gather_nnz=dev(g_nnz),
        gather_t_idx=dev(t_idx), gather_t_blk=dev(t_blk), gather_t_nnz=dev(tn),
        shape=(r, c), block=(br, bc), n_blocks=n_blocks)


def bcsr_to_dense(m: BlockCSR) -> Array:
    """Pure-jnp densification (jit-safe): scatter blocks back."""
    br, bc = m.block
    R, C = m.block_grid
    dense_blocks = jnp.zeros((R, C, br, bc), m.data.dtype)
    # slot s (>=1) belongs to block-row found from row_ptr; precompute rows on
    # host is not possible here (jit-safe path), so rebuild from gather tables.
    rr = jnp.repeat(jnp.arange(R), m.gather_idx.shape[1])
    cc = m.gather_idx.reshape(-1)
    ss = m.gather_blk.reshape(-1)
    blocks = m.data[ss]                      # (R*Jmax, br, bc); pad slots give 0
    dense_blocks = dense_blocks.at[rr, cc].add(blocks)
    return dense_blocks.transpose(0, 2, 1, 3).reshape(R * br, C * bc)


def bcsr_density(m: BlockCSR) -> float:
    R, C = m.block_grid
    return m.n_blocks / max(R * C, 1)


def pad_bcsr(m: BlockCSR, n_slots: int, jmax: int, jmax_t: int) -> BlockCSR:
    """Pad a BlockCSR's slot store and gather tables to fixed widths.

    Extra slots are zero blocks and extra gather columns point at slot 0 (the
    pad), so the kernel's output is unchanged. This makes BCSRs of different
    sparsity patterns shape-compatible, which is what lets per-layer
    compressed weights be ``jnp.stack``-ed and ridden through the layer-stack
    ``lax.scan`` (see sparse/compress.py). ``n_blocks`` is set to the padded
    slot count so the stacked metas compare equal; ``nbytes`` then reports
    the bytes actually stored.
    """
    cur_slots = m.data.shape[0]
    cur_j, cur_jt = m.gather_idx.shape[1], m.gather_t_idx.shape[1]
    assert n_slots >= cur_slots and jmax >= cur_j and jmax_t >= cur_jt, (
        (n_slots, jmax, jmax_t), (cur_slots, cur_j, cur_jt))

    def pad0(a, widths):
        return jnp.pad(a, widths)

    return BlockCSR(
        data=pad0(m.data, ((0, n_slots - cur_slots), (0, 0), (0, 0))),
        col_idx=pad0(m.col_idx, ((0, n_slots - cur_slots),)),
        row_ptr=m.row_ptr,
        gather_idx=pad0(m.gather_idx, ((0, 0), (0, jmax - cur_j))),
        gather_blk=pad0(m.gather_blk, ((0, 0), (0, jmax - cur_j))),
        gather_nnz=m.gather_nnz,
        gather_t_idx=pad0(m.gather_t_idx, ((0, 0), (0, jmax_t - cur_jt))),
        gather_t_blk=pad0(m.gather_t_blk, ((0, 0), (0, jmax_t - cur_jt))),
        gather_t_nnz=m.gather_t_nnz,
        shape=m.shape, block=m.block, n_blocks=n_slots - 1)


# ---------------------------------------------------------------------------
# PaletteBCSR — quantized block store (Deep Compression stage 2)
# ---------------------------------------------------------------------------

def pack_uint4(codes):
    """Pack uint8 codes < 16 two-per-byte along the last axis (must be even).

    Convention: byte k holds codes[2k] in the low nibble and codes[2k+1] in
    the high nibble, so ``unpack_uint4(pack_uint4(c)) == c``.
    """
    assert codes.shape[-1] % 2 == 0, codes.shape
    c = jnp.asarray(codes, jnp.uint8)
    return (c[..., 0::2] | (c[..., 1::2] << 4)).astype(jnp.uint8)


def unpack_uint4(packed):
    """Inverse of ``pack_uint4``: (..., n) uint8 -> (..., 2n) uint8 codes."""
    p = jnp.asarray(packed, jnp.uint8)
    lo = p & 0xF
    hi = p >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1],
                                                p.shape[-1] * 2)


def dequantize_codes(codes, palette, bits: int):
    """Palette lookup: codes (uint8, possibly nibble-packed) -> fp blocks.

    ``palette`` is (P,) for a single matrix, (L, P) for a stacked layer
    store, or (L, E, P) for a per-expert MoE stack (``codes`` carries the
    matching leading axes). jit-safe.
    """
    if bits == 4:
        codes = unpack_uint4(codes)

    def take(c, p):
        return jnp.take(p, c.astype(jnp.int32))

    if palette.ndim == 1:
        return take(codes, palette)
    lead = palette.shape[:-1]                   # stacked layer/expert axes
    cf = codes.reshape((-1,) + codes.shape[len(lead):])
    pf = palette.reshape(-1, palette.shape[-1])
    return jax.vmap(take)(cf, pf).reshape(codes.shape)


@partial(jax.tree_util.register_dataclass,
         data_fields=["codes", "palette", "col_idx", "row_ptr",
                      "gather_idx", "gather_blk", "gather_nnz",
                      "gather_t_idx", "gather_t_blk", "gather_t_nnz"],
         meta_fields=["shape", "block", "n_blocks", "bits"])
@dataclasses.dataclass(frozen=True)
class PaletteBCSR:
    """Palette-quantized BlockCSR: same index/gather structure as
    ``BlockCSR``, block data stored as palette codes.

    codes:   (n_slots, br, bc) uint8 at bits=8, (n_slots, br, bc//2) uint8
             with two nibble codes per byte at bits=4. Slot 0 stays the
             all-zero pad block (all codes 0).
    palette: (2**bits,) fp32 values; palette[0] == 0.0 exactly, so code 0
             reproduces intra-block zeros bit-exactly and the sparsity
             pattern is invariant under quantization.
    bits:    4 or 8 (static metadata — selects the kernel unpack path).

    Stacked layer stores carry a leading ``n_super`` axis on every array
    field (codes (L, n_slots, br, bc'), palette (L, 2**bits), ...), exactly
    like a stacked ``BlockCSR``, so the quantized stack rides through the
    layer ``lax.scan`` unchanged.
    """
    codes: Array
    palette: Array
    col_idx: Array
    row_ptr: Array
    gather_idx: Array
    gather_blk: Array
    gather_nnz: Array
    gather_t_idx: Array
    gather_t_blk: Array
    gather_t_nnz: Array
    shape: tuple[int, int]
    block: tuple[int, int]
    n_blocks: int
    bits: int

    @property
    def block_grid(self) -> tuple[int, int]:
        br, bc = self.block
        return (-(-self.shape[0] // br), -(-self.shape[1] // bc))

    @property
    def nnz(self) -> int:
        return self.n_blocks * self.block[0] * self.block[1]

    @property
    def nbytes(self) -> int:
        """Actual serving bytes: packed codes + palette + block indices.
        (codes are already nibble-packed at bits=4, so .size counts bytes)."""
        return sum(int(x.size) * x.dtype.itemsize
                   for x in (self.codes, self.palette,
                             self.col_idx, self.row_ptr))

    @property
    def bcsr_equiv_nbytes(self) -> int:
        """Bytes the same blocks would take as an unquantized fp32 BlockCSR
        (the denominator of the stage-2 compression ratio)."""
        n_entries = int(self.codes.size) * (2 if self.bits == 4 else 1)
        return n_entries * 4 + int(self.col_idx.size) * 4 \
            + int(self.row_ptr.size) * 4

    def dequantize(self) -> BlockCSR:
        """Expand to an fp BlockCSR with identical index/gather tables."""
        return BlockCSR(
            data=dequantize_codes(self.codes, self.palette, self.bits),
            col_idx=self.col_idx, row_ptr=self.row_ptr,
            gather_idx=self.gather_idx, gather_blk=self.gather_blk,
            gather_nnz=self.gather_nnz,
            gather_t_idx=self.gather_t_idx, gather_t_blk=self.gather_t_blk,
            gather_t_nnz=self.gather_t_nnz,
            shape=self.shape, block=self.block, n_blocks=self.n_blocks)

    def to_dense(self) -> Array:
        return bcsr_to_dense(self.dequantize())


# ---------------------------------------------------------------------------
# Elementwise CSR (paper-fidelity reference format)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "indices", "indptr"], meta_fields=["shape"])
@dataclasses.dataclass(frozen=True)
class CSR:
    """Paper Fig. 1(iii): ptr/indices/data elementwise CSR."""
    data: Array
    indices: Array
    indptr: Array
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def nbytes(self) -> int:
        return (self.data.size * self.data.dtype.itemsize
                + self.indices.size * 4 + self.indptr.size * 4)


def dense_to_csr(w) -> CSR:
    w = np.asarray(w)
    assert w.ndim == 2
    rows, cols = np.nonzero(w)
    indptr = np.zeros(w.shape[0] + 1, np.int32)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSR(data=jnp.asarray(w[rows, cols]),
               indices=jnp.asarray(cols.astype(np.int32)),
               indptr=jnp.asarray(indptr), shape=tuple(w.shape))


def csr_to_dense(m: CSR) -> Array:
    out = jnp.zeros(m.shape, m.data.dtype)
    nptr = np.asarray(m.indptr)
    rows = np.repeat(np.arange(m.shape[0]), nptr[1:] - nptr[:-1])
    return out.at[jnp.asarray(rows), m.indices].set(m.data)
