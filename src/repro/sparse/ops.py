"""Sparse op dispatch: Pallas kernel vs pure-jnp reference.

``sparse_matmul(x, w)`` is the serving-path matmul on compressed weights.
Backend selection:
  'pallas'    — the TPU kernel (interpret mode on CPU),
  'ref'       — densify + jnp (oracle; also the fastest choice on CPU),
  'auto'      — pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm import ops as kops
from repro.sparse.formats import BlockCSR


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_matmul(x, w: BlockCSR, backend: str = "auto"):
    """y = x @ w.T for BlockCSR w (paper forward dense x compressed')."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return kops.spmm_ad(x, w)
    if backend == "ref":
        return kops.spmm_fwd_ref(x, w).astype(x.dtype)
    raise ValueError(backend)


def sparse_matmul_t(dy, w: BlockCSR, backend: str = "auto"):
    """dx = dy @ w (paper backward dense x compressed)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return kops.spmm_t(dy, w)
    if backend == "ref":
        return kops.spmm_bwd_ref(dy, w).astype(dy.dtype)
    raise ValueError(backend)
