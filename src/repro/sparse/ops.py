"""Sparse op dispatch: Pallas kernel vs pure-jnp reference.

``sparse_matmul(x, w)`` is the matmul on compressed weights, used by BOTH the
serving path (forward only) and SpC-Retrain (paper §2.4, compressed
retraining). It carries a full ``custom_vjp``:

  forward   y  = x @ W'      (dense x compressed', bsr_spmm kernel)
  backward  dx = dy @ W      (dense x compressed, transposed gather tables)
            dw = SDDMM       (kernels/bsr_sddmm: gradients ONLY at the
                              resident BCSR slots — never a dense (out, in)
                              materialization, so compressed retraining's
                              FLOPs/bytes scale with nnz blocks)

Backend selection (shared by forward and backward so serve and train hit the
same kernel — ``resolve_backend`` is the single point of truth):
  'pallas'    — the TPU kernels (interpret mode on CPU),
  'ref'       — densify + jnp for the spmm products (oracle; fastest on CPU).
                The dw product still goes through the SDDMM kernel: the ref
                spmm densifies the *weight*, but the weight *gradient* is
                never materialized dense on any backend,
  'auto'      — pallas on TPU, ref elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_sddmm import ops as sddmm_kops
from repro.kernels.bsr_spmm import ops as kops
from repro.sparse.formats import BlockCSR


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """'auto' -> pallas on TPU, ref elsewhere; validates explicit choices.

    Both ``sparse_matmul`` and ``sparse_matmul_t`` (and the custom VJP that
    ties them together) resolve through here, so the forward serving kernel
    and the training backward always agree."""
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown sparse backend {backend!r}")
    return backend


def _fwd_product(x, w: BlockCSR, backend: str):
    if backend == "pallas":
        return kops.spmm(x, w)
    return kops.spmm_fwd_ref(x, w).astype(x.dtype)


def _bwd_dx_product(dy, w: BlockCSR, backend: str):
    if backend == "pallas":
        return kops.spmm_t(dy, w)
    return kops.spmm_bwd_ref(dy, w).astype(dy.dtype)


def _zero_cotangent(a):
    """Zero cotangent for a BlockCSR side array (float0 for int indices)."""
    if jnp.issubdtype(a.dtype, jnp.inexact):
        return jnp.zeros_like(a)
    return np.zeros(a.shape, jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sparse_matmul(backend: str, x, w: BlockCSR):
    return _fwd_product(x, w, backend)


def _sparse_matmul_fwd(backend, x, w):
    return _fwd_product(x, w, backend), (x, w)


def _sparse_matmul_bwd(backend, res, dy):
    x, w = res
    dx = _bwd_dx_product(dy, w, backend).astype(x.dtype)
    # dw via SDDMM at the resident slots only: (n_slots, br, bc) aligned
    # with w.data. The kernel runs in interpret mode off-TPU; there is no
    # dense (out, in) intermediate on any backend.
    dw_data = sddmm_kops.bsr_weight_grad(x, dy, w).astype(w.data.dtype)
    dw = BlockCSR(
        data=dw_data,
        col_idx=_zero_cotangent(w.col_idx),
        row_ptr=_zero_cotangent(w.row_ptr),
        gather_idx=_zero_cotangent(w.gather_idx),
        gather_blk=_zero_cotangent(w.gather_blk),
        gather_nnz=_zero_cotangent(w.gather_nnz),
        gather_t_idx=_zero_cotangent(w.gather_t_idx),
        gather_t_blk=_zero_cotangent(w.gather_t_blk),
        gather_t_nnz=_zero_cotangent(w.gather_t_nnz),
        shape=w.shape, block=w.block, n_blocks=w.n_blocks)
    return dx, dw


_sparse_matmul.defvjp(_sparse_matmul_fwd, _sparse_matmul_bwd)


def sparse_matmul(x, w: BlockCSR, backend: str = "auto"):
    """y = x @ w.T for BlockCSR w (paper forward dense x compressed').

    Differentiable in x (dense x compressed backward) AND in w.data (SDDMM
    masked weight gradient) — the compressed-retraining path."""
    return _sparse_matmul(resolve_backend(backend), x, w)


def sparse_matmul_t(dy, w: BlockCSR, backend: str = "auto"):
    """dx = dy @ w (paper backward dense x compressed)."""
    return _bwd_dx_product(dy, w, resolve_backend(backend))
