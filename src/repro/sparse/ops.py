"""Sparse op dispatch: Pallas kernel vs pure-jnp reference.

``sparse_matmul(x, w)`` is the matmul on compressed weights, used by BOTH the
serving path (forward only) and SpC-Retrain (paper §2.4, compressed
retraining). It carries a full ``custom_vjp``:

  forward   y  = x @ W'      (dense x compressed', bsr_spmm kernel)
  backward  dx = dy @ W      (dense x compressed, transposed gather tables)
            dw = SDDMM       (kernels/bsr_sddmm: gradients ONLY at the
                              resident BCSR slots — never a dense (out, in)
                              materialization, so compressed retraining's
                              FLOPs/bytes scale with nnz blocks)

Backend selection (shared by forward and backward so serve and train hit the
same kernel — ``resolve_backend`` is the single point of truth):
  'pallas'    — the TPU kernels (interpret mode on CPU),
  'ref'       — densify + jnp for the spmm products (oracle; fastest on CPU).
                The dw product still goes through the SDDMM kernel: the ref
                spmm densifies the *weight*, but the weight *gradient* is
                never materialized dense on any backend,
  'auto'      — pallas on TPU, ref elsewhere.

``sparse_matmul`` also accepts a ``PaletteBCSR`` (palette-quantized block
store, Deep Compression stage 2): the forward dequantizes resident blocks on
the fly — in-kernel on the pallas backend, dequantize-then-matmul on ref.
Serving-only: no weight gradient is defined for the quantized form.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_sddmm import ops as sddmm_kops
from repro.kernels.bsr_spmm import ops as kops
from repro.sparse.formats import BlockCSR, PaletteBCSR


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """'auto' -> pallas on TPU, ref elsewhere; validates explicit choices.

    Both ``sparse_matmul`` and ``sparse_matmul_t`` (and the custom VJP that
    ties them together) resolve through here, so the forward serving kernel
    and the training backward always agree."""
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown sparse backend {backend!r}")
    return backend


def _fwd_product(x, w: BlockCSR, backend: str):
    if backend == "pallas":
        return kops.spmm(x, w)
    return kops.spmm_fwd_ref(x, w).astype(x.dtype)


def _bwd_dx_product(dy, w: BlockCSR, backend: str):
    if backend == "pallas":
        return kops.spmm_t(dy, w)
    return kops.spmm_bwd_ref(dy, w).astype(dy.dtype)


def _zero_cotangent(a):
    """Zero cotangent for a BlockCSR side array (float0 for int indices)."""
    if jnp.issubdtype(a.dtype, jnp.inexact):
        return jnp.zeros_like(a)
    return np.zeros(a.shape, jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sparse_matmul(backend: str, x, w: BlockCSR):
    return _fwd_product(x, w, backend)


def _sparse_matmul_fwd(backend, x, w):
    return _fwd_product(x, w, backend), (x, w)


def _sparse_matmul_bwd(backend, res, dy):
    x, w = res
    dx = _bwd_dx_product(dy, w, backend).astype(x.dtype)
    # dw via SDDMM at the resident slots only: (n_slots, br, bc) aligned
    # with w.data. The kernel runs in interpret mode off-TPU; there is no
    # dense (out, in) intermediate on any backend.
    dw_data = sddmm_kops.bsr_weight_grad(x, dy, w).astype(w.data.dtype)
    # zero cotangents for every side array (float0 for int indices), real
    # gradient only at the block data — tree.map keeps the field list in
    # one place (the dataclass registration)
    dw = dataclasses.replace(jax.tree.map(_zero_cotangent, w), data=dw_data)
    return dx, dw


_sparse_matmul.defvjp(_sparse_matmul_fwd, _sparse_matmul_bwd)


def _palette_fwd_product(x, w: PaletteBCSR, backend: str):
    if backend == "pallas":
        return kops.spmm_palette(x, w)
    return kops.spmm_palette_fwd_ref(x, w).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _palette_matmul(backend: str, x, w: PaletteBCSR):
    return _palette_fwd_product(x, w, backend)


def _palette_matmul_fwd(backend, x, w):
    return _palette_fwd_product(x, w, backend), (x, w)


def _palette_matmul_bwd(backend, res, dy):
    x, w = res
    # dx through the dequantized weight — defined on BOTH backends so CPU
    # tests and TPU serving agree (the raw pallas_call has no VJP). The
    # quantized weight itself is a serving-time constant: codes/indices are
    # ints and the palette deliberately gets a zero cotangent — retraining
    # must go through dequantize_compressed().
    dx = _bwd_dx_product(dy, w.dequantize(), backend).astype(x.dtype)
    return dx, jax.tree.map(_zero_cotangent, w)


_palette_matmul.defvjp(_palette_matmul_fwd, _palette_matmul_bwd)


def sparse_matmul(x, w, backend: str = "auto"):
    """y = x @ w.T for compressed w (paper forward dense x compressed').

    ``w`` is a ``BlockCSR`` or a ``PaletteBCSR`` (Deep Compression stage 2;
    palette lookup fused into the kernel). The BlockCSR path is
    differentiable in x (dense x compressed backward) AND in w.data (SDDMM
    masked weight gradient) — the compressed-retraining path. PaletteBCSR is
    a *serving-only* weight format: differentiable in x on both backends
    (dx through the dequantized weight), but w is treated as a constant —
    quantize after debias (``sparse.compress.quantize_compressed``), or
    ``dequantize_compressed`` to resume retraining."""
    if isinstance(w, PaletteBCSR):
        return _palette_matmul(resolve_backend(backend), x, w)
    return _sparse_matmul(resolve_backend(backend), x, w)


def sparse_matmul_t(dy, w, backend: str = "auto"):
    """dx = dy @ w (paper backward dense x compressed). A ``PaletteBCSR``
    is dequantized first (same product the palette VJP's dx path uses)."""
    if isinstance(w, PaletteBCSR):
        w = w.dequantize()
    return _bwd_dx_product(dy, w, resolve_backend(backend))
