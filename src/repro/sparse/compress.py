"""Whole-model compression: dense params -> ``CompressedParams``.

This is the serving-side half of the paper's pipeline: after sparse-coding
training (or block magnitude pruning) has produced weights with whole zero
blocks, ``compress_params`` converts every compressible projection to
BlockCSR and returns a registered pytree that the model's apply functions
consume directly — the forward pass runs on the compressed representation
(EIE-style), and the checkpoint stores it (Deep-Compression-style).

Layout knowledge lives here, not in the model code: each target weight is
viewed as a 2D ``(out, in)`` matrix (the orientation ``sparse_matmul``
expects, ``y = x @ W'``):

    attention wq/wk/wv  (d, h, hd)  -> (h*hd, d)
    attention wo        (h, hd, d)  -> (d, h*hd)
    mlp wi/wg           (d, ff)     -> (ff, d)
    mlp wo              (ff, d)     -> (d, ff)
    moe ewi/ewg         (E, d, ff)  -> per-expert (ff, d)
    moe ewo             (E, ff, d)  -> per-expert (d, ff)
    rwkv tm r/k/v/g/o   (d, e)      -> (e, d)
    rwkv cm_k/cm_v/cm_r (d, ff)...  -> 2D transpose
    rg-lru in/gate/out  (d, w)...   -> 2D transpose
    head                (d, vocab)  -> (vocab, d)

Weights inside the scanned layer stack carry a leading ``n_super`` axis, and
MoE expert projections an additional per-expert axis — every leading stack
axis is treated the same way: each 2D slice is compressed separately, padded
to a uniform slot count (``formats.pad_bcsr``) and stacked, so the compressed
stack rides through ``lax.scan`` (layer axis) and ``lax.map`` (expert axis,
inside ``apply_moe``) exactly like the dense one. Matrices that don't
compress (too small, too dense, or BCSR bytes >= dense bytes) stay dense in
the residue — the ``CompressionPlan`` dense fallback.

When the plan sets ``quantize_bits`` (8 or 4, with per-layer overrides),
the emitted leaves are ``PaletteBCSR``: block data k-means-clustered to a
per-layer palette and stored as uint8 codes (Deep Compression stage 2) —
``quantize_compressed`` is also callable standalone as the last pipeline
stage, after debias retraining.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prox as prox_lib
from repro.core import quantize as quantize_lib
from repro.sparse.formats import (BlockCSR, PaletteBCSR, dense_to_bcsr,
                                  pack_uint4, pad_bcsr)

PyTree = Any

# per-layer sub-dicts and the projection names eligible for compression
_LAYER_TARGETS = {"attn": ("wq", "wk", "wv", "wo"),
                  "mlp": ("wi", "wg", "wo"),
                  "moe": ("ewi", "ewg", "ewo"),          # per-expert stacks
                  "tm": ("rwkv_r", "rwkv_k", "rwkv_v", "rwkv_g", "rwkv_o"),
                  "cm": ("cm_k", "cm_v", "cm_r"),
                  "rec": ("lru_in", "lru_gate", "lru_out")}

# MoE expert projections: the leading expert axis is a stack axis (compressed
# per expert, padded uniformly, stacked), exactly like the scanned layer axis
_PER_EXPERT = ("ewi", "ewg", "ewo")


def _lead_axes(name: str, stacked: bool) -> int:
    """Leading stack axes ahead of the per-matrix layout: the scanned layer
    axis (when inside ``layers/``), plus the per-expert axis for MoE."""
    return int(stacked) + int(name in _PER_EXPERT)


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """What to compress and how.

    block:        default (br, bc) BCSR tile, on the (out, in) view.
    min_sparsity: minimum fraction of all-zero blocks; below it the matrix
                  stays dense (fallback). For stacked layers the *worst*
                  slice must clear the bar (the stack compresses uniformly).
    min_size:     matrices with fewer elements stay dense.
    overrides:    ((path_substring, (br, bc)), ...) per-layer block sizes;
                  first match wins.
    quantize_bits: None keeps fp BlockCSR; 8 or 4 palette-quantizes the
                  block data (Deep Compression stage 2 — k-means palette,
                  code 0 reserved for exact zero) so ``compress_params``
                  emits ``PaletteBCSR`` leaves the runtime serves directly.
    quantize_overrides: ((path_substring, bits), ...) per-layer bit widths;
                  first match wins, bits 0 keeps that layer fp.
    slot_multiple: pad every BCSR slot count (pad slot 0 included) up to a
                  multiple of this, so the block store's slot axis divides a
                  mesh axis and shards instead of silently replicating
                  (small models easily land on odd slot counts). None =
                  auto: the lcm of the active mesh's axis sizes when
                  ``compress_params`` runs under ``use_mesh`` (or the
                  explicit value the launchers pass from ``--mesh``),
                  1 otherwise. Padding slots are zero blocks — output- and
                  gradient-invariant (``pad_bcsr``).
    """
    block: tuple[int, int] = (8, 128)
    min_sparsity: float = 0.5
    min_size: int = 4096
    overrides: tuple = ()
    quantize_bits: Optional[int] = None
    quantize_overrides: tuple = ()
    slot_multiple: Optional[int] = None

    def block_for(self, path: str) -> tuple[int, int]:
        for sub, blk in self.overrides:
            if sub in path:
                return tuple(blk)
        return self.block

    def bits_for(self, path: str) -> Optional[int]:
        """Palette bit width for a layer path (None = keep fp BlockCSR)."""
        for sub, bits in self.quantize_overrides:
            if sub in path:
                return int(bits) or None
        return self.quantize_bits


@partial(jax.tree_util.register_dataclass,
         data_fields=["dense", "sparse"], meta_fields=["plan"])
@dataclasses.dataclass
class CompressedParams:
    """Dense residue + {mirrored subtree: BlockCSR} sparse map.

    ``dense`` keeps the original tree structure; compressed leaves are
    replaced by zero-size placeholders (so the layer-stack scan still sees a
    leaf with the right leading axis). ``sparse`` mirrors the params nesting
    ("layers"/<layer>/("attn"|"mlp")/<name>, "rem"/..., "head") with BlockCSR
    leaves — stacked over ``n_super`` for the scanned layers.
    """
    dense: PyTree
    sparse: PyTree
    plan: CompressionPlan


def _is_bcsr(x) -> bool:
    return isinstance(x, (BlockCSR, PaletteBCSR))


# ---------------------------------------------------------------------------
# (out, in) orientation
# ---------------------------------------------------------------------------

def _as_out_in(path: str, arr: np.ndarray) -> Optional[np.ndarray]:
    """View a stored weight as the 2D (out, in) matrix the kernel consumes."""
    leaf = path.rsplit("/", 1)[-1]
    if arr.ndim == 2:
        return np.ascontiguousarray(arr.T)
    if arr.ndim == 3 and "/attn/" in f"/{path}/":
        if leaf in ("wq", "wk", "wv"):          # (d, heads, hd)
            return np.ascontiguousarray(arr.reshape(arr.shape[0], -1).T)
        if leaf == "wo":                        # (heads, hd, d)
            return np.ascontiguousarray(arr.reshape(-1, arr.shape[-1]).T)
    return None


def _from_out_in(path: str, mat: np.ndarray, orig_shape) -> np.ndarray:
    """Inverse of ``_as_out_in``: back to the stored layout."""
    return np.ascontiguousarray(mat.T).reshape(orig_shape)


# ---------------------------------------------------------------------------
# Block pruning aligned to the plan (serving-side Pru baseline)
# ---------------------------------------------------------------------------

def _prune_blocks_2d(mat: np.ndarray, block: tuple[int, int],
                     sparsity: float) -> np.ndarray:
    """Zero the lowest-L2 fraction of (br, bc) blocks of a (out, in) view."""
    br, bc = block
    r, c = mat.shape
    pr, pc = (-r) % br, (-c) % bc
    mp = np.pad(mat, ((0, pr), (0, pc)))
    R, C = mp.shape[0] // br, mp.shape[1] // bc
    blocks = mp.reshape(R, br, C, bc).transpose(0, 2, 1, 3).copy()
    norms = np.sqrt((blocks.astype(np.float64) ** 2).sum(axis=(2, 3)))
    k = int(round(sparsity * norms.size))
    if k > 0:
        flat = norms.ravel()
        kill = np.zeros(flat.size, bool)
        kill[np.argsort(flat, kind="stable")[:k]] = True
        blocks[kill.reshape(R, C)] = 0
    mp = blocks.transpose(0, 2, 1, 3).reshape(R * br, C * bc)
    return mp[:r, :c]


def prune_blocks_for_plan(params: PyTree, plan: CompressionPlan,
                          sparsity: float) -> PyTree:
    """Magnitude-prune whole blocks on the plan's (out, in) BCSR grid.

    Unstructured magnitude pruning leaves ~every MXU-sized block occupied,
    so nothing would compress; this is the block-aligned variant that makes
    the compressed runtime real for a Pru-style serving flow.
    """
    def handle(path, arr):
        view = _as_out_in(path, arr)
        if view is None or view.size < plan.min_size:
            return arr
        pruned = _prune_blocks_2d(view, plan.block_for(path), sparsity)
        return jnp.asarray(_from_out_in(path, pruned, arr.shape),
                           dtype=arr.dtype)

    return _walk_targets(params, handle)


def _walk_targets(params: PyTree, handle) -> PyTree:
    """Apply ``handle(path, arr)`` to every compressible leaf, copying the
    tree. Leading stack axes (scanned layers, MoE experts) are handled
    slice-wise with a uniform outcome."""
    out = jax.tree.map(lambda x: x, params)   # structural copy

    def per_layer(layer, path, stacked):
        for sub, names in _LAYER_TARGETS.items():
            if sub not in layer:
                continue
            for name in names:
                if name not in layer[sub]:
                    continue
                arr = np.asarray(layer[sub][name])
                p = f"{path}/{sub}/{name}"
                lead = _lead_axes(name, stacked)
                if lead:
                    flat = arr.reshape((-1,) + arr.shape[lead:])
                    slices = [np.asarray(handle(p, s)) for s in flat]
                    layer[sub][name] = jnp.asarray(
                        np.stack(slices).reshape(arr.shape), dtype=arr.dtype)
                else:
                    layer[sub][name] = jnp.asarray(handle(p, arr),
                                                   dtype=arr.dtype)

    for lkey, layer in out.get("layers", {}).items():
        per_layer(layer, f"layers/{lkey}", stacked=True)
    for lkey, layer in out.get("rem", {}).items():
        per_layer(layer, f"rem/{lkey}", stacked=False)
    if "head" in out:
        out["head"] = jnp.asarray(handle("head", np.asarray(out["head"])),
                                  dtype=out["head"].dtype)
    return out


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def _resolve_slot_multiple(plan: CompressionPlan) -> int:
    """Slot-axis packing multiple: the plan's explicit value, else the lcm
    of the ambient mesh's axis sizes (any axis the per-path row rule maps
    to then divides the slot count), else 1 (no packing)."""
    if plan.slot_multiple is not None:
        return max(int(plan.slot_multiple), 1)
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return 1
    return int(np.lcm.reduce([int(s) for s in mesh.shape.values()]))


def _try_compress(arr: np.ndarray, path: str, plan: CompressionPlan,
                  n_stack: int) -> Optional[BlockCSR]:
    """``n_stack`` leading axes of ``arr`` are stack axes (scanned layers
    and/or MoE experts); each remaining-slice is compressed separately,
    padded to uniform slot counts and stacked back field-wise."""
    slices = (list(arr.reshape((-1,) + arr.shape[n_stack:])) if n_stack
              else [arr])
    views = [_as_out_in(path, s) for s in slices]
    if views[0] is None or views[0].size < plan.min_size:
        return None
    block = plan.block_for(path)
    ms = [dense_to_bcsr(v, block) for v in views]
    grid = int(np.prod(ms[0].block_grid))
    if min(1.0 - m.n_blocks / max(grid, 1) for m in ms) < plan.min_sparsity:
        return None
    # Zero-slot edge case: an all-zero (fully pruned / fully group-l1'd)
    # slice yields n_blocks == 0 — only the pad slot 0 exists. That is a
    # VALID empty BCSR (gather tables are all-pad, the kernel returns 0),
    # and padding it up alongside non-empty slices is also fine because
    # pad_bcsr only appends zero blocks + pad gather entries. The one
    # hazard is gradient flow to pad slots, which bsr_sddmm masks via
    # slot_coordinates' validity vector.
    n_slots = max(m.data.shape[0] for m in ms)
    mult = _resolve_slot_multiple(plan)
    n_slots = -(-n_slots // mult) * mult     # mesh-divisible slot packing
    jmax = max(m.gather_idx.shape[1] for m in ms)
    jmax_t = max(m.gather_t_idx.shape[1] for m in ms)
    ms = [pad_bcsr(m, n_slots, jmax, jmax_t) for m in ms]
    if ms[0].nbytes >= views[0].size * views[0].dtype.itemsize:
        return None                           # dense fallback: no byte win
    if not n_stack:
        return ms[0]
    out = jax.tree.map(lambda *xs: jnp.stack(xs), *ms)
    if n_stack > 1:                           # e.g. (L, E, ...) MoE stacks
        out = jax.tree.map(
            lambda a: a.reshape(arr.shape[:n_stack] + a.shape[1:]), out)
    return out


def _placeholder(arr, n_stack: int):
    return jnp.zeros(arr.shape[:n_stack], arr.dtype)


def compress_params(params: PyTree,
                    plan: Optional[CompressionPlan] = None) -> CompressedParams:
    """Convert every plan-eligible projection to BlockCSR.

    Returns ``CompressedParams(dense=residue, sparse=bcsr_map, plan=plan)``.
    The residue keeps placeholders where weights were compressed; everything
    else (norms, embeddings, recurrent/MoE params) stays dense.
    """
    plan = plan or CompressionPlan()
    dense = jax.tree.map(lambda x: x, params)
    sparse: dict = {}

    def per_layer(layer, path, stacked, sp_out):
        for sub, names in _LAYER_TARGETS.items():
            if sub not in layer:
                continue
            for name in names:
                if name not in layer[sub]:
                    continue
                arr = np.asarray(layer[sub][name])
                lead = _lead_axes(name, stacked)
                m = _try_compress(arr, f"{path}/{sub}/{name}", plan, lead)
                if m is None:
                    continue
                sp_out.setdefault(sub, {})[name] = m
                layer[sub][name] = _placeholder(arr, lead)

    if "layers" in dense:
        for lkey, layer in dense["layers"].items():
            sp: dict = {}
            per_layer(layer, f"layers/{lkey}", True, sp)
            if sp:
                sparse.setdefault("layers", {})[lkey] = sp
    for lkey, layer in dense.get("rem", {}).items():
        sp = {}
        per_layer(layer, f"rem/{lkey}", False, sp)
        if sp:
            sparse.setdefault("rem", {})[lkey] = sp
    if "head" in dense:
        m = _try_compress(np.asarray(dense["head"]), "head", plan, 0)
        if m is not None:
            sparse["head"] = m
            dense["head"] = _placeholder(np.asarray(dense["head"]), 0)
    cp = CompressedParams(dense=dense, sparse=sparse, plan=plan)
    if plan.quantize_bits or plan.quantize_overrides:
        cp = quantize_compressed(cp)            # emit PaletteBCSR leaves
    return cp


# ---------------------------------------------------------------------------
# Palette quantization (Deep Compression stage 2: BlockCSR -> PaletteBCSR)
# ---------------------------------------------------------------------------

def quantize_bcsr(m: BlockCSR, bits: int, iters: int = 25) -> PaletteBCSR:
    """k-means palette-quantize a BlockCSR's block store (host-side).

    Per layer slice (stacked stores quantize each leading-axis slice — layer
    and, for MoE, each expert — with its own palette): cluster the NONZERO
    block entries to 2**bits - 1 values
    via ``core.quantize.kmeans_palette`` and reserve code 0 for exact zero —
    intra-block zeros, the pad slot 0, and ``pad_bcsr`` padding slots all
    map to code 0 and reproduce bit-exactly, so the sparsity pattern (and
    every index/gather table, shared by reference) is invariant. At 4 bits
    codes are nibble-packed two-per-byte.
    """
    if bits not in (4, 8):
        raise ValueError(f"palette bits must be 4 or 8, got {bits}")
    br, bc = m.block
    if bits == 4 and bc % 2:
        raise ValueError(f"bits=4 nibble packing needs even bc, got {m.block}")
    data = np.asarray(jax.device_get(m.data))
    lead = data.shape[:-3]                      # (L,) layers, (L, E) MoE, ()
    slices = data.reshape((-1,) + data.shape[-3:]) if lead else data[None]
    n_levels = (1 << bits) - 1                  # code 0 is reserved for 0.0
    codes_l, pal_l = [], []
    for sl in slices:
        palette, _, assign = quantize_lib.kmeans_palette(
            jnp.asarray(sl), n_levels, iters=iters)
        codes = np.where(sl.reshape(-1) != 0,
                         np.asarray(assign).astype(np.int64) + 1,
                         0).astype(np.uint8).reshape(sl.shape)
        pal = np.zeros((1 << bits,), np.float32)
        pal[1:] = np.asarray(palette)
        codes_l.append(codes)
        pal_l.append(pal)
    codes = np.stack(codes_l).reshape(data.shape) if lead else codes_l[0]
    pal = (np.stack(pal_l).reshape(lead + (1 << bits,)) if lead
           else pal_l[0])
    codes = jnp.asarray(codes)
    if bits == 4:
        codes = pack_uint4(codes)
    return PaletteBCSR(
        codes=codes, palette=jnp.asarray(pal),
        col_idx=m.col_idx, row_ptr=m.row_ptr,
        gather_idx=m.gather_idx, gather_blk=m.gather_blk,
        gather_nnz=m.gather_nnz,
        gather_t_idx=m.gather_t_idx, gather_t_blk=m.gather_t_blk,
        gather_t_nnz=m.gather_t_nnz,
        shape=m.shape, block=m.block, n_blocks=m.n_blocks, bits=bits)


def quantize_compressed(cp: CompressedParams,
                        bits: Optional[int] = None) -> CompressedParams:
    """Quantize every BlockCSR leaf of a ``CompressedParams`` to
    ``PaletteBCSR`` per the plan's ``bits_for`` (or a blanket ``bits``
    argument, which also updates the stored plan). The LAST pipeline stage:
    run after debias retraining — the quantized form is serving-only.
    Already-quantized leaves pass through unchanged."""
    plan = cp.plan
    if bits is not None:
        plan = dataclasses.replace(plan, quantize_bits=bits)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cp.sparse,
                                                         is_leaf=_is_bcsr)
    leaves = []
    for path, leaf in flat:
        b = plan.bits_for(_path_str(path)) if isinstance(leaf, BlockCSR) \
            else None
        leaves.append(quantize_bcsr(leaf, b) if b else leaf)
    return CompressedParams(dense=cp.dense,
                            sparse=jax.tree_util.tree_unflatten(treedef,
                                                                leaves),
                            plan=plan)


def dequantize_compressed(cp: CompressedParams) -> CompressedParams:
    """Inverse runtime conversion: expand every PaletteBCSR back to an fp
    BlockCSR (values are the palette entries — lossy vs the pre-quantization
    weights, lossless vs the quantized model). Use to resume mask-frozen
    retraining from a quantized checkpoint."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cp.sparse,
                                                         is_leaf=_is_bcsr)
    leaves = [leaf.dequantize() if isinstance(leaf, PaletteBCSR) else leaf
              for _, leaf in flat]
    return CompressedParams(dense=cp.dense,
                            sparse=jax.tree_util.tree_unflatten(treedef,
                                                                leaves),
                            plan=cp.plan)


# ---------------------------------------------------------------------------
# Plan-aligned training prox (SpC-Retrain: train *into* the BCSR grid)
# ---------------------------------------------------------------------------

_ATTN_QKV = ("wq", "wk", "wv")


def _norm_keystr(path: str) -> str:
    """jax keystr "['layers']['b0_attn']['mlp']['wi']" -> "layers/b0_attn/mlp/wi"
    (the path format ``CompressionPlan.block_for`` and this module use)."""
    parts = re.findall(r"\['([^']+)'\]", path)
    return "/".join(parts) if parts else path.strip("/").lstrip(".")


def make_plan_prox(plan: CompressionPlan) -> Callable:
    """Path-aware block group-l1 prox on the SAME (out, in) grid
    ``compress_params`` tiles.

    The optimizer's prox sees weights in their *stored* layouts (stacked
    (L, d, ff) MLPs, (L, d, h, hd) attention, ...) while the BCSR grid lives
    on the 2D (out, in) view. Block partitions map through transpose, so
    shrinking (bc, br) tiles of the flattened (in, out) view is exactly the
    plan's (br, bc) group-l1 on (out, in): whole blocks of the serving grid
    hit exact zero during training and ``compress_params`` then needs no
    prune step. Non-plan-eligible leaves (embeddings, leaves under
    ``min_size``, ...) are left UNTOUCHED: the group-l1 lambda is calibrated
    against block norms (~sqrt(block_size) larger than element magnitudes),
    so an elementwise-l1 fallback at the same lambda would annihilate e.g. a
    tied embedding/head in one step.

    Returned callable has signature ``prox_fn(z, tau, path="")`` — the
    ``path`` keyword is how ``ProxOptimizer`` detects path-awareness.
    """

    def prox_fn(z, tau, path: str = ""):
        p = _norm_keystr(path)
        leaf = p.rsplit("/", 1)[-1]
        stacked = p.startswith("layers/")
        nd = z.ndim - (1 if stacked else 0)     # per-layer rank
        wrapped = f"/{p}/"

        def _in(sub, rank) -> bool:
            return (f"/{sub}/" in wrapped and leaf in _LAYER_TARGETS[sub]
                    and nd == rank)

        eligible = (
            ("/attn/" in wrapped and leaf in _LAYER_TARGETS["attn"]
             and nd in (2, 3))
            or _in("mlp", 2)
            or _in("moe", 3)                    # per-expert (E, in, out)
            or _in("tm", 2) or _in("cm", 2) or _in("rec", 2)
            or (leaf == "head" and nd == 2))
        if not eligible:
            return z
        br, bc = plan.block_for(p)

        def prox2d(flat):
            if flat.size < plan.min_size:
                return flat
            # (in, out) view with transposed tiles == plan grid on (out, in)
            return prox_lib.prox_group_l1_blocks(flat, tau, block=(bc, br))

        def one(zi):
            shp = zi.shape
            if leaf in _PER_EXPERT:                    # (E, in, out) stack
                return jax.vmap(prox2d)(zi)
            if zi.ndim == 3 and leaf in _ATTN_QKV:     # (d, h, hd): in, out
                flat = zi.reshape(shp[0], -1)
            elif zi.ndim == 3:                         # attn wo (h, hd, d)
                flat = zi.reshape(-1, shp[-1])
            else:                                      # 2D stored (in, out)
                flat = zi
            return prox2d(flat).reshape(shp)

        return jax.vmap(one)(z) if stacked else one(z)

    return prox_fn


# ---------------------------------------------------------------------------
# Mask-frozen retraining from a compressed model (paper §2.4 debias)
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def split_trainable(cp: CompressedParams):
    """Split a ``CompressedParams`` into (trainable pytree, rebuild fn).

    ``trainable = {"dense": residue, "bcsr_data": {path: BlockCSR.data}}``
    contains only float arrays, so it can be handed straight to
    ``jax.value_and_grad`` / a ``ProxOptimizer``; ``rebuild(trainable)``
    plants the (possibly updated) data blocks back into the BlockCSR
    structures. Index/gather tables are closure constants: retraining *from*
    a compressed checkpoint updates only resident block data (+ the dense
    residue) — the sparsity pattern is frozen by construction, and
    ``masks.zero_mask(trainable)`` additionally freezes intra-block zeros
    and pad slots.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cp.sparse,
                                                         is_leaf=_is_bcsr)
    for path, leaf in flat:
        if isinstance(leaf, PaletteBCSR):
            raise TypeError(
                f"split_trainable got a PaletteBCSR at {_path_str(path)}: "
                "quantized weights are serving-only — debias before "
                "quantize_compressed(), or dequantize_compressed() first")
    data = {_path_str(path): leaf.data for path, leaf in flat}
    trainable = {"dense": cp.dense, "bcsr_data": data}
    plan = cp.plan
    # keep only the index/gather structure in the closure (zero-size data
    # slice): rebuild always overwrites data, and retaining the original
    # blocks would pin a second full copy of the compressed weights for the
    # whole debias phase
    structs = [(path, dataclasses.replace(leaf, data=leaf.data[:0]))
               for path, leaf in flat]

    def rebuild(tr) -> CompressedParams:
        leaves = [dataclasses.replace(leaf, data=tr["bcsr_data"][_path_str(p)])
                  for p, leaf in structs]
        sparse = jax.tree_util.tree_unflatten(treedef, leaves)
        return CompressedParams(dense=tr["dense"], sparse=sparse, plan=plan)

    return trainable, rebuild


def densify_compressed(cp: CompressedParams, like: PyTree) -> PyTree:
    """Inverse of ``compress_params``: scatter BCSR blocks back into a dense
    param tree shaped like ``like`` (the mask-frozen dense reference used to
    validate debiased compressed logits; host-side, test/debug only).

    Values come from ``cp`` — the residue from ``cp.dense`` and the
    compressed projections from the BCSR blocks; ``like`` only supplies the
    stored shapes that the zero-size placeholders erased."""
    def merge(l, d):
        da = np.asarray(d)
        if da.shape != np.shape(np.asarray(l)):      # placeholder: use like
            return np.asarray(l).copy()
        return da.copy()

    out = jax.tree.map(merge, like, cp.dense)

    def to_stored(sl, path: str, orig_shape):
        mat = np.asarray(sl.to_dense())[:sl.shape[0], :sl.shape[1]]
        return _from_out_in(path, mat, orig_shape)

    for name, m in iter_bcsr(cp):
        keys = name.split("/")
        tgt = out
        for k in keys[:-1]:
            tgt = tgt[k]
        ref = np.asarray(tgt[keys[-1]])
        store = m.codes if isinstance(m, PaletteBCSR) else m.data
        lead = store.ndim - 3                   # layer and/or expert axes
        if lead:
            mats = [to_stored(jax.tree.map(lambda a, i=i: a[i], m),
                              name, ref.shape[lead:])
                    for i in np.ndindex(*store.shape[:lead])]
            tgt[keys[-1]] = np.stack(mats).reshape(ref.shape) \
                .astype(ref.dtype)
        else:
            tgt[keys[-1]] = to_stored(m, name, ref.shape).astype(ref.dtype)
    return jax.tree.map(jnp.asarray, out)


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def iter_bcsr(cp: CompressedParams):
    """Yield (path, BlockCSR) over the sparse map."""
    flat, _ = jax.tree_util.tree_flatten_with_path(cp.sparse, is_leaf=_is_bcsr)
    for path, leaf in flat:
        if _is_bcsr(leaf):
            yield _path_str(path), leaf


def compressed_size_bytes(cp: CompressedParams) -> int:
    """Actual serving bytes: dense residue + real BCSR storage (data +
    col_idx + row_ptr), not a hypothetical CSR table."""
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(cp.dense))
    total += sum(m.nbytes for _, m in iter_bcsr(cp))
    return int(total)


def bcsr_equiv_size_bytes(cp: CompressedParams) -> int:
    """What ``compressed_size_bytes`` would report with every palette leaf
    expanded back to fp32 BlockCSR — the stage-1 baseline the quantized
    total is compared against (docs/size_accounting.md)."""
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(cp.dense))
    for _, m in iter_bcsr(cp):
        total += m.bcsr_equiv_nbytes if isinstance(m, PaletteBCSR) \
            else m.nbytes
    return int(total)


def format_size_report(dense_bytes: int, bcsr_bytes: int,
                       palette_bytes: Optional[int] = None) -> str:
    """One-line dense-vs-compressed byte report (shared by serve/train CLIs).

    ``bcsr_bytes`` is the fp BlockCSR total; pass ``palette_bytes`` after
    quantization to also report the stage-2 (palette) total and ratio.
    See docs/size_accounting.md for how each term is computed."""
    line = (f"model size dense={dense_bytes/2**20:.2f}MB "
            f"bcsr={bcsr_bytes/2**20:.2f}MB "
            f"({dense_bytes/max(bcsr_bytes, 1):.1f}x)")
    if palette_bytes is not None:
        line += (f" palette={palette_bytes/2**20:.2f}MB "
                 f"({dense_bytes/max(palette_bytes, 1):.1f}x)")
    return line


def compression_summary(cp: CompressedParams) -> str:
    """Per-layer breakdown: format, block occupancy and actual stored bytes
    per compressed matrix, plus a dense-residue / total footer. This is the
    table ``launch/serve --sparse`` prints; docs/size_accounting.md documents
    every column."""
    lines = [f"{'weight':44s} {'(out, in)':>14s} {'block':>10s} "
             f"{'fmt':>6s} {'blocks':>14s} {'bytes':>10s}"]
    sparse_total = 0
    for name, m in iter_bcsr(cp):
        grid = int(np.prod(m.block_grid))
        store = m.codes if isinstance(m, PaletteBCSR) else m.data
        lead = store.ndim - 3                   # layer and/or expert axes
        n = int(np.prod(store.shape[:lead])) if lead else 1
        fmt = f"pal{m.bits}" if isinstance(m, PaletteBCSR) else "bcsr"
        sparse_total += m.nbytes
        lines.append(
            f"{name:44s} {str(m.shape):>14s} {str(m.block):>10s} "
            f"{fmt:>6s} {m.n_blocks:>6d}/{grid:<7d} {m.nbytes:>10d}"
            + (f"  x{n} slices" if lead else ""))
    dense_residue = sum(int(l.size) * l.dtype.itemsize
                        for l in jax.tree.leaves(cp.dense))
    lines.append(f"{'dense residue (embeddings/norms/fallback)':92s} "
                 f"{dense_residue:>10d}")
    lines.append(f"{'total serving bytes':92s} "
                 f"{sparse_total + dense_residue:>10d}")
    return "\n".join(lines)
