"""Shared model layers: norms, embeddings, RoPE, MLPs, sparse-servable dense.

Functional style: ``init_*`` returns a param dict; ``apply`` fns are pure.
Logical-axis sharding annotations go through distributed.sharding.shard_ann
(no-op outside a mesh context). Compute dtype is configurable; params are
kept in param_dtype (fp32 master weights by default).

``apply_mlp`` and ``apply_head`` take an optional ``sparse_weights`` map of
BlockCSR matrices in (out, in) layout; present entries dispatch
``sparse_ops.sparse_matmul`` instead of the dense einsum — the compressed
serving path (weights built by ``repro.sparse.compress.compress_params``;
the dense param may then be a zero-size placeholder and is never touched).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_ann
from repro.sparse import ops as sparse_ops
from repro.sparse.formats import BlockCSR

Array = jax.Array


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    """He-style fan-in init (paper uses He init for ReLU nets)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = (scale / fan_in) ** 0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["norm_bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: dict, x: Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["norm_bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int) -> dict:
    return {"embedding": truncated_normal_init(key, (vocab, d), 1.0)}


def apply_embed(p: dict, tokens: Array, compute_dtype) -> Array:
    emb = p["embedding"].astype(compute_dtype)
    x = jnp.take(emb, tokens, axis=0)
    return shard_ann(x, ("batch", "seq", "embed"))


def apply_head(p: dict, x: Array, tie: bool, softcap: Optional[float],
               sparse_weights: Optional[dict[str, BlockCSR]] = None) -> Array:
    if sparse_weights and "head" in sparse_weights:
        # compressed serving path: head stored (vocab, d) BCSR. Input goes
        # up to fp32 so the logits keep the dense branch's fp32 accumulation
        # (the ref backend returns results in the input dtype).
        xs = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        logits = sparse_ops.sparse_matmul(xs, sparse_weights["head"])
        logits = logits.reshape(*x.shape[:-1], -1)
    else:
        w = p["embedding"] if tie else p["head"]
        # matmul in compute dtype with fp32 accumulation: keeps the (huge)
        # embedding FSDP gather in bf16 instead of f32 (§Perf iteration C4)
        w = w.astype(x.dtype)
        eq = "...d,vd->...v" if tie else "...d,dv->...v"
        logits = jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return shard_ann(logits, ("batch", "seq", "vocab"))


def apply_proj(p: dict, x: Array, name: str,
               sparse: Optional[dict] = None) -> Array:
    """y = x @ p[name] for a stored 2D (in, out) projection.

    A ``BlockCSR``/``PaletteBCSR`` entry in ``sparse`` (stored (out, in) by
    ``compress_params``) dispatches ``sparse_matmul`` instead of the einsum
    — the single dense-or-compressed dispatch shared by the RWKV
    time/channel-mix and RG-LRU serve-from-compressed paths."""
    if sparse and name in sparse:
        y = sparse_ops.sparse_matmul(x.reshape(-1, x.shape[-1]),
                                     sparse[name])
        return y.reshape(*x.shape[:-1], -1).astype(x.dtype)
    return jnp.einsum("...d,do->...o", x, p[name].astype(x.dtype))


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    if name == "sigmoid":
        return jax.nn.sigmoid
    raise ValueError(name)


# ---------------------------------------------------------------------------
# MLP (gated / plain), with an optional BCSR serving path
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, gated: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": truncated_normal_init(ks[0], (d, ff), 2.0),
         "wo": truncated_normal_init(ks[1], (ff, d), 2.0)}
    if gated:
        p["wg"] = truncated_normal_init(ks[2], (d, ff), 2.0)
    return p


def apply_mlp(p: dict, x: Array, act: str, gated: bool,
              sparse_weights: Optional[dict[str, BlockCSR]] = None) -> Array:
    """If ``sparse_weights`` maps a param name to a BlockCSR, the compressed
    kernel path is used for that projection (serving mode)."""
    f = activation(act)
    dt = x.dtype

    def mm(name, h):
        if sparse_weights and name in sparse_weights:
            # BCSR stores W as (out, in): y = h @ W' via the paper's kernel
            hs = h.reshape(-1, h.shape[-1])
            y = sparse_ops.sparse_matmul(hs, sparse_weights[name])
            return y.reshape(*h.shape[:-1], -1).astype(dt)
        return jnp.einsum("...d,df->...f", h, p[name].astype(dt))

    h = mm("wi", x)
    h = shard_ann(h, ("batch", "seq", "mlp"))
    if gated:
        g = mm("wg", x)
        h = f(g) * h
    else:
        h = f(h)
    out = mm("wo", h)
    return shard_ann(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
