"""The paper's four experiment networks (paper §4): LeNet-5 (MNIST) and
AlexNet / VGG16 / ResNet-32 (CIFAR-10), reconstructed so layer-wise parameter
counts match the paper's Tables A1-A4 exactly:

  lenet5:   conv1 500, conv2 25,000, fc1 400,000, fc2 5,000   (total 430,500)
  alexnet:  grouped convs (groups=2 on conv2/4/5) -> 7,558,176 weights
  vgg16:    13 convs + fc 512->1024->1024->10      -> 16,293,568 weights
  resnet32: 16/32/64 stages, 1x1 projections        ->    464,432 weights

Functional init/apply pairs; weights-only counts (biases excluded from
compression, as in the paper). Convolutions use lax.conv_general_dilated in
NHWC; the sparse serving path reshapes filters to (C_out, C_in*kh*kw) BCSR.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal_init

Array = jax.Array


def conv_init(key, kh, kw, cin, cout, groups=1):
    # HWIO layout; He init (paper uses He et al. 2015)
    return truncated_normal_init(key, (kh, kw, cin // groups, cout), 2.0)


def conv(x, w, stride=1, padding="SAME", groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


@dataclasses.dataclass(frozen=True)
class CNNModel:
    name: str
    input_shape: tuple
    n_classes: int
    init: Callable
    apply: Callable


# ---------------------------------------------------------------------------
# LeNet-5 (Caffe variant; paper Table A1)
# ---------------------------------------------------------------------------

def _lenet_init(key):
    ks = jax.random.split(key, 4)
    return {
        "conv1": {"w": conv_init(ks[0], 5, 5, 1, 20)},      # 500
        "conv2": {"w": conv_init(ks[1], 5, 5, 20, 50)},     # 25,000
        "fc1": {"w": truncated_normal_init(ks[2], (800, 500), 2.0),
                "bias": jnp.zeros((500,))},                  # 400,000
        "fc2": {"w": truncated_normal_init(ks[3], (500, 10), 2.0),
                "bias": jnp.zeros((10,))},                   # 5,000
    }


def _lenet_apply(p, x):
    x = maxpool(conv(x, p["conv1"]["w"], padding="VALID"))   # 28->24->12
    x = maxpool(conv(x, p["conv2"]["w"], padding="VALID"))   # 12->8->4
    x = x.reshape(x.shape[0], -1)                            # 4*4*50 = 800
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["bias"])
    return x @ p["fc2"]["w"] + p["fc2"]["bias"]


# ---------------------------------------------------------------------------
# AlexNet-CIFAR (grouped convs; paper Table A2)
# ---------------------------------------------------------------------------

_ALEX = [  # (k, cin, cout, groups, pool)
    (5, 3, 96, 1, True),       # conv1   7,200
    (5, 96, 256, 2, True),     # conv2 307,200
    (3, 256, 384, 1, False),   # conv3 884,736
    (3, 384, 384, 2, False),   # conv4 663,552
    (3, 384, 256, 2, True),    # conv5 442,368
]


def _alex_init(key):
    ks = jax.random.split(key, 8)
    p = {}
    for i, (k, cin, cout, g, _) in enumerate(_ALEX):
        p[f"conv{i+1}"] = {"w": conv_init(ks[i], k, k, cin, cout, g)}
    p["fc1"] = {"w": truncated_normal_init(ks[5], (4096, 1024), 2.0),
                "bias": jnp.zeros((1024,))}                  # 4,194,304
    p["fc2"] = {"w": truncated_normal_init(ks[6], (1024, 1024), 2.0),
                "bias": jnp.zeros((1024,))}                  # 1,048,576
    p["fc3"] = {"w": truncated_normal_init(ks[7], (1024, 10), 2.0),
                "bias": jnp.zeros((10,))}                    # 10,240
    return p


def _alex_apply(p, x):
    for i, (k, cin, cout, g, pool) in enumerate(_ALEX):
        x = jax.nn.relu(conv(x, p[f"conv{i+1}"]["w"], groups=g))
        if pool:
            x = maxpool(x)                                   # 32->16->8->4
    x = x.reshape(x.shape[0], -1)                            # 4*4*256 = 4096
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["bias"])
    x = jax.nn.relu(x @ p["fc2"]["w"] + p["fc2"]["bias"])
    return x @ p["fc3"]["w"] + p["fc3"]["bias"]


# ---------------------------------------------------------------------------
# VGG16-CIFAR (paper Table A3)
# ---------------------------------------------------------------------------

_VGG = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]


def _vgg_init(key):
    ks = jax.random.split(key, 16)
    p = {}
    cin, ki = 3, 0
    for bi, (cout, reps) in enumerate(_VGG):
        for ri in range(reps):
            p[f"conv{bi+1}-{ri+1}"] = {"w": conv_init(ks[ki], 3, 3, cin, cout)}
            cin = cout
            ki += 1
    p["fc1"] = {"w": truncated_normal_init(ks[13], (512, 1024), 2.0),
                "bias": jnp.zeros((1024,))}                  # 524,288
    p["fc2"] = {"w": truncated_normal_init(ks[14], (1024, 1024), 2.0),
                "bias": jnp.zeros((1024,))}                  # 1,048,576
    p["fc3"] = {"w": truncated_normal_init(ks[15], (1024, 10), 2.0),
                "bias": jnp.zeros((10,))}
    return p


def _vgg_apply(p, x):
    for bi, (cout, reps) in enumerate(_VGG):
        for ri in range(reps):
            x = jax.nn.relu(conv(x, p[f"conv{bi+1}-{ri+1}"]["w"]))
        x = maxpool(x)                                       # 32->16->8->4->2->1
    x = x.reshape(x.shape[0], -1)                            # 512
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["bias"])
    x = jax.nn.relu(x @ p["fc2"]["w"] + p["fc2"]["bias"])
    return x @ p["fc3"]["w"] + p["fc3"]["bias"]


# ---------------------------------------------------------------------------
# ResNet-32 (CIFAR; paper Table A4: 5 blocks per stage, 16/32/64)
# ---------------------------------------------------------------------------

def _res_init(key):
    n = 5
    keys = iter(jax.random.split(key, 64))
    p = {"conv1": {"w": conv_init(next(keys), 3, 3, 3, 16)}}     # 432
    cin = 16
    for si, cout in enumerate([16, 32, 64]):
        for bi in range(n):
            stride_proj = (si > 0 and bi == 0)
            blk = {
                "c1": {"w": conv_init(next(keys), 3, 3, cin, cout)},
                "c2": {"w": conv_init(next(keys), 3, 3, cout, cout)},
            }
            if stride_proj:
                blk["proj"] = {"w": conv_init(next(keys), 1, 1, cin, cout)}
            p[f"conv{si+1}-{bi+1}"] = blk
            cin = cout
    p["fc1"] = {"w": truncated_normal_init(next(keys), (64, 10), 2.0),
                "bias": jnp.zeros((10,))}                        # 640
    return p


def _res_apply(p, x):
    x = jax.nn.relu(conv(x, p["conv1"]["w"]))
    for si, cout in enumerate([16, 32, 64]):
        for bi in range(5):
            blk = p[f"conv{si+1}-{bi+1}"]
            stride = 2 if (si > 0 and bi == 0) else 1
            h = jax.nn.relu(conv(x, blk["c1"]["w"], stride=stride))
            h = conv(h, blk["c2"]["w"])
            if "proj" in blk:
                x = conv(x, blk["proj"]["w"], stride=stride)
            x = jax.nn.relu(x + h)
    x = avgpool_global(x)
    return x @ p["fc1"]["w"] + p["fc1"]["bias"]


CNN_ZOO = {
    "lenet5": CNNModel("lenet5", (28, 28, 1), 10, _lenet_init, _lenet_apply),
    "alexnet-cifar": CNNModel("alexnet-cifar", (32, 32, 3), 10,
                              _alex_init, _alex_apply),
    "vgg16-cifar": CNNModel("vgg16-cifar", (32, 32, 3), 10,
                            _vgg_init, _vgg_apply),
    "resnet32-cifar": CNNModel("resnet32-cifar", (32, 32, 3), 10,
                               _res_init, _res_apply),
}


def weight_count(params) -> int:
    """Weights-only count (paper excludes biases from its totals)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return sum(l.size for path, l in flat
               if "bias" not in jax.tree_util.keystr(path))
