"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(w_a * u_t + b_a)         (recurrence gate, elementwise)
    i_t = sigmoid(w_x * u_t + b_x)         (input gate, elementwise)
    log a_t = -c * softplus(A) * r_t       (A learned per channel, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is a linear first-order scan -> jax.lax.associative_scan
(log-depth, MXU-free but VPU parallel) for train/prefill; decode is the
single-step update carried in the layer state.

Block structure (Griffin "recurrent block"):
    y = W_out( GeLU(W_gate x)  *  RGLRU(conv1d(W_in x)) )

Gates use elementwise (per-channel) weights; the reference implementation
uses block-diagonal projections — a documented simplification (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_ann
from repro.models.layers import apply_proj, truncated_normal_init

Array = jax.Array
_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv1d_width
    ks = jax.random.split(key, 4)
    # A init so that a = exp(-c*softplus(A)) spans ~(0.9, 0.999)
    a_init = jnp.linspace(-2.0, 1.0, w)
    return {
        "lru_in": truncated_normal_init(ks[0], (d, w), 1.0),
        "lru_gate": truncated_normal_init(ks[1], (d, w), 1.0),
        "lru_out": truncated_normal_init(ks[2], (w, d), 1.0),
        "conv1d": truncated_normal_init(ks[3], (cw, w), 1.0),
        "rglru_a_param": a_init,             # excluded from regularization
        "gate_w_a": jnp.zeros((w,)), "gate_b_a": jnp.zeros((w,)),
        "gate_w_x": jnp.zeros((w,)), "gate_b_x": jnp.zeros((w,)),
    }


def _causal_conv(u: Array, kern: Array, state: Array | None):
    """u: (B, S, w); kern: (cw, w) depthwise causal conv.

    state: (B, cw-1, w) trailing context from the previous step (decode) or
    None (train: left-zero-padded).
    """
    cw = kern.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ux = jnp.concatenate([pad, u], axis=1)          # (B, S+cw-1, w)
    out = sum(ux[:, i:i + u.shape[1]] * kern[i].astype(u.dtype)
              for i in range(cw))
    new_state = ux[:, -(cw - 1):] if cw > 1 else pad
    return out, new_state


def _rglru_coeffs(p: dict, u: Array):
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(p["gate_w_a"] * u32 + p["gate_b_a"])
    i = jax.nn.sigmoid(p["gate_w_x"] * u32 + p["gate_b_x"])
    log_a = -_C * jax.nn.softplus(p["rglru_a_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u32)
    return a, b


def _lru_scan(a: Array, b: Array) -> Array:
    """Associative scan of h_t = a_t * h_{t-1} + b_t over axis 1 (f32)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_scan(p: dict, u: Array, h0: Array | None = None) -> tuple[Array, Array]:
    """u: (B, S, w) -> (h (B, S, w), h_last (B, w)). Linear scan h=a*h+b."""
    a, b = _rglru_coeffs(p, u)
    if h0 is not None:
        # fold the carried state into the first step's offset
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    h = _lru_scan(a, b)
    return h.astype(u.dtype), h[:, -1]


def rglru_step(p: dict, u: Array, h: Array) -> tuple[Array, Array]:
    """Single decode step. u: (B, 1, w); h: (B, w)."""
    a, b = _rglru_coeffs(p, u)
    h2 = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h2[:, None].astype(u.dtype), h2


def apply_rglru_block(p: dict, x: Array, cfg: ModelConfig,
                      state: dict | None = None,
                      sparse: dict | None = None):
    """Griffin recurrent block. state None => train/prefill full-sequence.

    Returns (y, new_state) where state = {"h": (B,w), "conv": (B,cw-1,w)}.
    ``sparse``: optional {"lru_in"|"lru_gate"|"lru_out": BlockCSR}
    compressed projections (the three width-changing matmuls; the depthwise
    conv and elementwise gates stay dense residue).
    """
    gate = jax.nn.gelu(apply_proj(p, x, "lru_gate", sparse))
    u = apply_proj(p, x, "lru_in", sparse)
    u = shard_ann(u, ("batch", "seq", "lru"))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv1d"], conv_state)
    if state is None:
        h, h_last = rglru_scan(p, u)
    else:
        h, h_last = rglru_step(p, u, state["h"])
    h = shard_ann(h, ("batch", "seq", "lru"))
    y = apply_proj(p, gate * h, "lru_out", sparse)
    y = shard_ann(y, ("batch", "seq", "embed"))
    return y, {"h": h_last, "conv": new_conv}


def apply_rglru_block_paged(p: dict, x: Array, cfg: ModelConfig, state: dict,
                            n_tokens: Array, sparse: dict | None = None):
    """Slot-pooled Griffin recurrent block — the continuous-batching
    engine's mixed step (any mix of prefill chunks and 1-token decodes).

    x: (B, C, d) — B engine slots, slot i carrying ``n_tokens[i]`` valid
    tokens (0 = inactive). state is the slot-indexed state pool
    {"h": (B, w) f32, "conv": (B, cw-1, w)}. Invalid tail positions are
    masked with identity scan coefficients (a=1, b=0 — exact in IEEE), so
    the scan's last element equals the state after exactly ``n_tokens``
    updates: chunked prefill matches the full-sequence scan and inactive
    slots keep their state bit-exactly. The conv trailing context is
    re-gathered at each slot's own valid length.
    """
    cw = cfg.conv1d_width
    c = x.shape[1]
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n_tokens[:, None]

    gate = jax.nn.gelu(apply_proj(p, x, "lru_gate", sparse))
    u = apply_proj(p, x, "lru_in", sparse)
    u = shard_ann(u, ("batch", "seq", "lru"))

    # Depthwise causal conv against the carried trailing context. Valid
    # positions only ever read positions <= themselves (a valid prefix),
    # so no input masking is needed; the new context is gathered at each
    # slot's own n_tokens (c=0 slots re-select their old pad exactly).
    kern = p["conv1d"]
    pad = state["conv"].astype(u.dtype)
    ux = jnp.concatenate([pad, u], axis=1)          # (B, C+cw-1, w)
    u = sum(ux[:, i:i + c] * kern[i].astype(u.dtype) for i in range(cw))
    if cw > 1:
        idx = n_tokens[:, None] + jnp.arange(cw - 1, dtype=jnp.int32)
        new_conv = jnp.take_along_axis(ux, idx[:, :, None], axis=1)
    else:
        new_conv = pad
    new_conv = new_conv.astype(state["conv"].dtype)

    a, b = _rglru_coeffs(p, u)
    a = jnp.where(valid[..., None], a, 1.0)
    b = jnp.where(valid[..., None], b, 0.0)
    b = b.at[:, 0].add(a[:, 0] * state["h"])        # fold carried h0
    h = _lru_scan(a, b)
    h_last = h[:, -1]
    h = shard_ann(h.astype(u.dtype), ("batch", "seq", "lru"))
    y = apply_proj(p, gate * h, "lru_out", sparse)
    y = shard_ann(y, ("batch", "seq", "embed"))
    return y, {"h": h_last, "conv": new_conv}


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}
