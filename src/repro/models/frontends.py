"""Modality frontend STUBS (per the assignment).

``[vlm]`` (paligemma) and ``[audio]`` (musicgen) specify the transformer
backbone only; the SigLIP vision tower / EnCodec codec are represented by
*precomputed* patch/frame embeddings. These helpers produce the stand-in
embedding specs (dry-run) and synthetic embeddings (smoke tests/examples).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def input_embedding_spec(cfg: ModelConfig, batch: int, seq: int,
                         dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in for frontend-provided embeddings."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)


def synthetic_embeddings(key, cfg: ModelConfig, batch: int, seq: int,
                         dtype=jnp.float32):
    """Deterministic fake patch/frame embeddings for smoke tests."""
    return 0.02 * jax.random.normal(key, (batch, seq, cfg.d_model), dtype)
