"""build(name_or_config) -> Model, plus input-spec construction for every
(arch x shape) dry-run cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import frontends
from repro.models.transformer import Model, make_model


def build(arch, reduced: bool = False, remat: bool = True) -> Model:
    cfg = get_config(arch) if isinstance(arch, str) else arch
    if reduced:
        cfg = cfg.reduced()
    return make_model(cfg, remat=remat)


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str,
                data_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for one dry-run cell (no allocation).

    train/prefill: {"inputs": (B, S) ids or (B, S, d) frontend embeddings,
                    "labels": (B, S)}
    decode:        {"inputs": (B, 1) or (B, 1, d)} -- cache specs come from
                   Model.init_cache under jax.eval_shape (launch/dryrun.py).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend != "none":
            inputs = frontends.input_embedding_spec(cfg, b, s, data_dtype)
        else:
            inputs = jax.ShapeDtypeStruct((b, s), tok)
        return {"inputs": inputs, "labels": jax.ShapeDtypeStruct((b, s), tok)}
    # decode: one new token against a seq_len-deep cache
    if cfg.frontend != "none":
        inputs = frontends.input_embedding_spec(cfg, b, 1, data_dtype)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1), tok)
    return {"inputs": inputs}
