"""Composable decoder stack covering all assigned architectures.

A config's ``block_pattern`` (e.g. ("attn",) or ("rglru","rglru","attn") or
("rwkv",)) defines a *super-block*; the stack is a ``lax.scan`` over
``n_super_blocks`` stacked copies (HLO/compile-time O(1) in depth) plus an
unrolled remainder (RecurrentGemma's 38 = 12x3 + 2). Each pattern element is
a full layer: mixer (attention / RG-LRU / RWKV time-mix) + FFN (MLP / MoE /
RWKV channel-mix), pre-norm residuals.

Three entry points per model:
    apply_train(params, batch)            full-sequence forward -> logits, aux
    decode_step(params, tok, cache, pos)  one token + cache -> logits, cache
    prefill(params, prompt, cache)        whole prompt -> last logits, cache

All are pure functions built by ``make_model(cfg)``; remat policy for the
scan body is configurable (train memory).

**Compressed runtime**: every entry point accepts either a raw param tree or
a ``repro.sparse.compress.CompressedParams``. The sparse map mirrors the
params nesting and its BlockCSR leaves are stacked over ``n_super`` (padded
to a uniform slot count), so compressed weights ride through the layer-stack
``lax.scan`` next to the dense residue; attention QKV/O, MLP, MoE expert
(per-expert stacks, ``lax.map`` inside ``apply_moe``), RWKV time/channel-mix,
RG-LRU and head projections with a BCSR entry dispatch ``sparse_matmul`` —
the paper's inference-in-compressed-form, whole-model and
architecture-complete.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_ann
from repro.models import attention, moe as moe_lib, rglru, rwkv6
from repro.models.layers import (apply_embed, apply_head, apply_mlp,
                                 apply_norm, init_embed, init_mlp, init_norm,
                                 truncated_normal_init)
from repro.sparse.compress import CompressedParams

Array = jax.Array
PyTree = Any


def _split_params(params) -> tuple[PyTree, Optional[PyTree]]:
    """Accept raw params or CompressedParams everywhere.

    Returns (dense_residue, sparse_map-or-None); the sparse map mirrors the
    params nesting with BlockCSR leaves (stacked over n_super under
    "layers", so it scans alongside the dense stack).
    """
    if isinstance(params, CompressedParams):
        return params.dense, params.sparse
    return params, None


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"pre_norm": init_norm(cfg.d_model, cfg.norm)}
    if kind == "attn":
        p["attn"] = attention.init_attention(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = rglru.init_rglru(ks[0], cfg)
    elif kind == "rwkv":
        p["tm"] = rwkv6.init_time_mix(ks[0], cfg)
    else:
        raise ValueError(kind)
    p["ffn_norm"] = init_norm(cfg.d_model, cfg.norm)
    if kind == "rwkv":
        p["cm"] = rwkv6.init_channel_mix(ks[1], cfg)
    elif cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    return p


def _zero_aux():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


def _apply_layer_train(p: dict, x: Array, cfg: ModelConfig, kind: str,
                       positions: Array, sp: Optional[dict] = None
                       ) -> tuple[Array, dict]:
    sp = sp or {}
    h = apply_norm(p["pre_norm"], x, cfg.norm)
    if kind == "attn":
        mix = attention.apply_attention(p["attn"], h, cfg, positions,
                                        sparse=sp.get("attn"))
    elif kind == "rglru":
        mix, _ = rglru.apply_rglru_block(p["rec"], h, cfg, None,
                                         sparse=sp.get("rec"))
    elif kind == "rwkv":
        mix, _ = rwkv6.apply_time_mix(p["tm"], h, cfg, None,
                                      sparse=sp.get("tm"))
    x = x + mix
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    aux = _zero_aux()
    if kind == "rwkv":
        f, _ = rwkv6.apply_channel_mix(p["cm"], h, None,
                                       sparse=sp.get("cm"))
    elif cfg.moe is not None:
        f, aux = moe_lib.apply_moe(p["moe"], h, cfg, sparse=sp.get("moe"))
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, cfg.mlp_gated,
                      sparse_weights=sp.get("mlp"))
    return x + f, aux


def _apply_layer_decode(p: dict, x: Array, cfg: ModelConfig, kind: str,
                        cache: dict, pos: Array, sp: Optional[dict] = None
                        ) -> tuple[Array, dict]:
    sp = sp or {}
    h = apply_norm(p["pre_norm"], x, cfg.norm)
    new_cache = dict(cache)
    if kind == "attn":
        mix, new_cache["attn"] = attention.decode_attention(
            p["attn"], h, cache["attn"], pos, cfg, sparse=sp.get("attn"))
    elif kind == "rglru":
        mix, new_cache["rec"] = rglru.apply_rglru_block(
            p["rec"], h, cfg, cache["rec"], sparse=sp.get("rec"))
    elif kind == "rwkv":
        mix, new_cache["tm"] = rwkv6.apply_time_mix(
            p["tm"], h, cfg, cache["tm"], sparse=sp.get("tm"))
    x = x + mix
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    if kind == "rwkv":
        f, new_cache["cm"] = rwkv6.apply_channel_mix(p["cm"], h, cache["cm"],
                                                     sparse=sp.get("cm"))
    elif cfg.moe is not None:
        f, _ = moe_lib.apply_moe(p["moe"], h, cfg, sparse=sp.get("moe"))
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, cfg.mlp_gated,
                      sparse_weights=sp.get("mlp"))
    return x + f, new_cache


def _apply_layer_prefill(p: dict, x: Array, cfg: ModelConfig, kind: str,
                         cache: dict, positions: Array,
                         sp: Optional[dict] = None) -> tuple[Array, dict]:
    """Full-sequence forward that also produces the post-prompt cache state.

    Recurrent kinds run their train-path full-sequence scan from a fresh
    state (the prompt starts at position 0) and keep the final state;
    attention fills the ring KV cache in one write."""
    sp = sp or {}
    h = apply_norm(p["pre_norm"], x, cfg.norm)
    new_cache = dict(cache)
    if kind == "attn":
        mix, new_cache["attn"] = attention.prefill_attention(
            p["attn"], h, cache["attn"], positions, cfg, sparse=sp.get("attn"))
    elif kind == "rglru":
        mix, new_cache["rec"] = rglru.apply_rglru_block(
            p["rec"], h, cfg, None, sparse=sp.get("rec"))
    elif kind == "rwkv":
        mix, new_cache["tm"] = rwkv6.apply_time_mix(p["tm"], h, cfg, None,
                                                    sparse=sp.get("tm"))
    x = x + mix
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    if kind == "rwkv":
        f, new_cache["cm"] = rwkv6.apply_channel_mix(p["cm"], h, None,
                                                     sparse=sp.get("cm"))
    elif cfg.moe is not None:
        f, _ = moe_lib.apply_moe(p["moe"], h, cfg, sparse=sp.get("moe"))
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, cfg.mlp_gated,
                      sparse_weights=sp.get("mlp"))
    return x + f, new_cache


def _apply_layer_paged(p: dict, x: Array, cfg: ModelConfig, kind: str,
                       cache: dict, page_table: Array, positions: Array,
                       n_tokens: Array, sp: Optional[dict] = None,
                       attn_backend: Optional[str] = None,
                       kv_splits: int = 1) -> tuple[Array, dict]:
    """Mixed prefill/decode layer against the slot resource pool tree (the
    continuous-batching engine path). Attention layers use block-paged KV
    pools; recurrent mixers (rglru/rwkv) use slot-indexed state pools — no
    paging, O(1) per slot — with chunked prefill handled by intra-chunk
    scans (``apply_rglru_block_paged`` / ``apply_time_mix_paged``).
    ``attn_backend``/``kv_splits`` select the paged-attention kernel
    (see ``attention.paged_attention``)."""
    if kind not in ("attn", "rglru", "rwkv"):
        raise NotImplementedError(
            f"layer kind {kind!r} has no slot resource pool — the engine "
            "covers attn/rglru/rwkv; use the sequential serving path "
            "(launch/serve without --engine)")
    sp = sp or {}
    if kind != "attn":
        # A slot whose FIRST prefill chunk lands this tick (absolute
        # position 0) starts a new request: zero its recurrent state
        # in-step, shape-stably — state left by a previous occupant of the
        # slot must not leak in. (The engine also zeroes recycled slots
        # host-side; this in-step reset is the correctness invariant.)
        fresh = (positions[:, 0] == 0) & (n_tokens > 0)
        cache = jax.tree.map(
            lambda l: jnp.where(
                fresh.reshape((-1,) + (1,) * (l.ndim - 1)),
                jnp.zeros_like(l), l),
            cache)
    h = apply_norm(p["pre_norm"], x, cfg.norm)
    new_cache = dict(cache)
    if kind == "attn":
        mix, new_cache["attn"] = attention.paged_attention(
            p["attn"], h, cache["attn"], page_table, positions, n_tokens, cfg,
            sparse=sp.get("attn"), backend=attn_backend, kv_splits=kv_splits)
    elif kind == "rglru":
        mix, new_cache["rec"] = rglru.apply_rglru_block_paged(
            p["rec"], h, cfg, cache["rec"], n_tokens, sparse=sp.get("rec"))
    elif kind == "rwkv":
        mix, new_cache["tm"] = rwkv6.apply_time_mix_paged(
            p["tm"], h, cfg, cache["tm"], n_tokens, sparse=sp.get("tm"))
    x = x + mix
    h = apply_norm(p["ffn_norm"], x, cfg.norm)
    if kind == "rwkv":
        f, new_cache["cm"] = rwkv6.apply_channel_mix_paged(
            p["cm"], h, cache["cm"], n_tokens, sparse=sp.get("cm"))
    elif cfg.moe is not None:
        f, _ = moe_lib.apply_moe(p["moe"], h, cfg, sparse=sp.get("moe"))
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, cfg.mlp_gated,
                      sparse_weights=sp.get("mlp"))
    return x + f, new_cache


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype) -> dict:
    if kind == "attn":
        return {"attn": attention.init_kv_cache(cfg, batch, seq_len, dtype)}
    if kind == "rglru":
        return {"rec": rglru.init_rglru_state(cfg, batch, dtype)}
    if kind == "rwkv":
        st = rwkv6.init_rwkv_state(cfg, batch)
        return {"tm": st["tm"], "cm": st["cm"]}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Super-block (one pattern repeat)
# ---------------------------------------------------------------------------

def _init_super(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}_{kind}": _init_layer(ks[i], cfg, kind)
            for i, kind in enumerate(cfg.block_pattern)}


def _super_train(p: dict, x: Array, cfg: ModelConfig, positions: Array,
                 sp: Optional[dict] = None):
    sp = sp or {}
    aux = _zero_aux()
    for i, kind in enumerate(cfg.block_pattern):
        key = f"b{i}_{kind}"
        x, a = _apply_layer_train(p[key], x, cfg, kind, positions,
                                  sp.get(key))
        aux = jax.tree.map(jnp.add, aux, a)
    # sequence-parallel residual carry: the inter-layer (bwd-residual) x is
    # seq-sharded over 'model' so the layer-stack residual shrinks by the
    # TP degree (no-op when seq doesn't divide / no mesh). RWKV blocks are
    # exempt: token-shift ddlerp + chunked WKV consume full sequences five
    # ways per block, and the re-gathers cost more than the carry saves
    # (measured 3x memory-term regression; EXPERIMENTS.md §Perf).
    if "rwkv" not in cfg.block_pattern:
        x = shard_ann(x, ("batch", "res_seq", "embed"))
    return x, aux


def _super_decode(p: dict, x: Array, cfg: ModelConfig, cache: dict, pos,
                  sp: Optional[dict] = None):
    sp = sp or {}
    new_cache = {}
    for i, kind in enumerate(cfg.block_pattern):
        key = f"b{i}_{kind}"
        x, new_cache[key] = _apply_layer_decode(p[key], x, cfg, kind,
                                                cache[key], pos, sp.get(key))
    return x, new_cache


def _super_prefill(p: dict, x: Array, cfg: ModelConfig, cache: dict,
                   positions: Array, sp: Optional[dict] = None):
    sp = sp or {}
    new_cache = {}
    for i, kind in enumerate(cfg.block_pattern):
        key = f"b{i}_{kind}"
        x, new_cache[key] = _apply_layer_prefill(p[key], x, cfg, kind,
                                                 cache[key], positions,
                                                 sp.get(key))
    return x, new_cache


def _super_paged(p: dict, x: Array, cfg: ModelConfig, cache: dict,
                 page_table: Array, positions: Array, n_tokens: Array,
                 sp: Optional[dict] = None,
                 attn_backend: Optional[str] = None, kv_splits: int = 1):
    sp = sp or {}
    new_cache = {}
    for i, kind in enumerate(cfg.block_pattern):
        key = f"b{i}_{kind}"
        x, new_cache[key] = _apply_layer_paged(p[key], x, cfg, kind,
                                               cache[key], page_table,
                                               positions, n_tokens,
                                               sp.get(key),
                                               attn_backend=attn_backend,
                                               kv_splits=kv_splits)
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    """Every apply fn accepts raw params OR ``CompressedParams`` — the
    compressed-model runtime: BCSR weights take the sparse_matmul path in
    attention/MLP/head, everything else reads the dense residue."""
    cfg: ModelConfig
    init: Callable
    apply_train: Callable       # (params, batch) -> (logits, aux)
    apply_hidden: Callable      # (params, batch) -> (hidden, aux)  [no head]
    head: Callable              # (params, hidden) -> logits
    decode_step: Callable       # (params, x, cache, pos) -> (logits, cache)
    prefill: Callable           # (params, prompt, cache) -> (logits, cache)
    init_cache: Callable        # (batch, seq_len, dtype) -> cache
    # (params, tokens, pools, page_table, start_pos, n_tokens)
    #   -> (last-valid-token logits, pools) — the continuous-batching
    # engine's mixed step (serve/engine.py) over the slot resource pool
    # tree: block-paged KV for attention layers, slot-indexed state pools
    # for recurrent mixers. None only for layer kinds outside
    # attn/rglru/rwkv coverage.
    paged_step: Optional[Callable] = None


def make_model(cfg: ModelConfig, remat: bool = True,
               remat_policy: str = "nothing") -> Model:
    """remat_policy: 'nothing' (save only the per-layer carry — minimal
    memory, bwd recomputes the layer; §Perf iteration C2) or 'dots' (save
    projection outputs — less recompute, ~6x the residual memory)."""
    cdt = _dtype(cfg.compute_dtype)
    n_super = cfg.n_super_blocks
    rem = cfg.remainder_pattern
    policy = (jax.checkpoint_policies.nothing_saveable
              if remat_policy == "nothing"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def init(key) -> PyTree:
        k_emb, k_layers, k_rem, k_head = jax.random.split(key, 4)
        params: dict = {"embed": init_embed(k_emb, cfg.vocab, cfg.d_model)}
        sub_keys = jax.random.split(k_layers, n_super)
        params["layers"] = jax.vmap(
            lambda kk: _init_super(kk, cfg))(sub_keys)
        if rem:
            rks = jax.random.split(k_rem, len(rem))
            params["rem"] = {f"r{i}_{kind}": _init_layer(rks[i], cfg, kind)
                             for i, kind in enumerate(rem)}
        params["final_norm"] = init_norm(cfg.d_model, cfg.norm)
        if not cfg.tie_embeddings:
            params["head"] = truncated_normal_init(
                k_head, (cfg.d_model, cfg.vocab), 1.0)
        return params

    def embed_inputs(params, inputs):
        """Token ids (B, S) int32, or precomputed embeddings (B, S, d) for
        stub frontends (vlm/audio per the assignment)."""
        if inputs.ndim == 3:                     # frontend stub: embeddings
            return inputs.astype(cdt)
        return apply_embed(params["embed"], inputs, cdt)

    def head(params, x):
        dense, sparse = _split_params(params)
        x = apply_norm(dense["final_norm"], x, cfg.norm)
        hp = {"embedding": dense["embed"]["embedding"]} if cfg.tie_embeddings \
            else {"head": dense["head"]}
        sw = {"head": sparse["head"]} if sparse and "head" in sparse else None
        return apply_head(hp, x, cfg.tie_embeddings, cfg.logit_softcap,
                          sparse_weights=sw)

    def apply_hidden(params, batch) -> tuple[Array, dict]:
        dense, sparse = _split_params(params)
        sp_layers = (sparse or {}).get("layers", {})
        sp_rem = (sparse or {}).get("rem", {})
        inputs = batch["inputs"]
        x = embed_inputs(dense, inputs)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(carry, xs):
            layer_p, layer_sp = xs
            x, aux = carry
            x2, a = _super_train(layer_p, x, cfg, positions, layer_sp)
            return (x2, jax.tree.map(jnp.add, aux, a)), None

        body_fn = body
        if remat:
            body_fn = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body_fn, (x, _zero_aux()),
                                   (dense["layers"], sp_layers))
        for i, kind in enumerate(rem):
            x, a = _apply_layer_train(dense["rem"][f"r{i}_{kind}"], x, cfg,
                                      kind, positions,
                                      sp_rem.get(f"r{i}_{kind}"))
            aux = jax.tree.map(jnp.add, aux, a)
        return x, aux

    def apply_train(params, batch) -> tuple[Array, dict]:
        x, aux = apply_hidden(params, batch)
        return head(params, x), aux

    def init_cache(batch: int, seq_len: int, dtype=None) -> PyTree:
        dtype = dtype or cdt
        def one_super():
            return {f"b{i}_{kind}": _init_layer_cache(cfg, kind, batch,
                                                      seq_len, dtype)
                    for i, kind in enumerate(cfg.block_pattern)}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super,) + x.shape).copy(),
            one_super())
        cache = {"layers": stacked}
        if rem:
            cache["rem"] = {f"r{i}_{kind}": _init_layer_cache(
                cfg, kind, batch, seq_len, dtype)
                for i, kind in enumerate(rem)}
        return cache

    def decode_step(params, inputs, cache, pos) -> tuple[Array, PyTree]:
        """inputs: (B, 1) ids or (B, 1, d) embeddings; pos: scalar int32."""
        dense, sparse = _split_params(params)
        sp_layers = (sparse or {}).get("layers", {})
        sp_rem = (sparse or {}).get("rem", {})
        x = embed_inputs(dense, inputs)

        def body(x, xs):
            layer_p, layer_c, layer_sp = xs
            x2, c2 = _super_decode(layer_p, x, cfg, layer_c, pos, layer_sp)
            return x2, c2

        x, new_layer_cache = jax.lax.scan(
            body, x, (dense["layers"], cache["layers"], sp_layers))
        new_cache = {"layers": new_layer_cache}
        if rem:
            new_cache["rem"] = {}
            for i, kind in enumerate(rem):
                key = f"r{i}_{kind}"
                x, new_cache["rem"][key] = _apply_layer_decode(
                    dense["rem"][key], x, cfg, kind, cache["rem"][key], pos,
                    sp_rem.get(key))
        return head(params, x), new_cache

    def prefill(params, inputs, cache) -> tuple[Array, PyTree]:
        """Consume the whole prompt in one forward, filling the cache.

        inputs: (B, S) ids or (B, S, d) embeddings. Returns (last-position
        logits (B, vocab), cache ready for decode at pos = S) — one jit
        dispatch instead of S stepwise decodes."""
        dense, sparse = _split_params(params)
        sp_layers = (sparse or {}).get("layers", {})
        sp_rem = (sparse or {}).get("rem", {})
        x = embed_inputs(dense, inputs)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, xs):
            layer_p, layer_c, layer_sp = xs
            x2, c2 = _super_prefill(layer_p, x, cfg, layer_c, positions,
                                    layer_sp)
            return x2, c2

        x, new_layer_cache = jax.lax.scan(
            body, x, (dense["layers"], cache["layers"], sp_layers))
        new_cache = {"layers": new_layer_cache}
        if rem:
            new_cache["rem"] = {}
            for i, kind in enumerate(rem):
                key = f"r{i}_{kind}"
                x, new_cache["rem"][key] = _apply_layer_prefill(
                    dense["rem"][key], x, cfg, kind, cache["rem"][key],
                    positions, sp_rem.get(key))
        return head(params, x[:, -1:])[:, 0], new_cache

    def paged_step(params, tokens, pools, page_table, start_pos, n_tokens,
                   backend: Optional[str] = None, kv_splits: int = 1
                   ) -> tuple[Array, PyTree]:
        """Continuous-batching mixed step over a fixed-capacity slot batch.

        tokens: (B, C) ids — up to C new tokens per slot (decode slots carry
        1, prefill slots a chunk, inactive slots 0 — see ``n_tokens``);
        pools: paged KV tree from ``serve.paged_kv.init_paged_cache``;
        page_table: (B, P) int32; start_pos/n_tokens: (B,) int32. Returns
        (logits at each slot's LAST valid token (B, vocab), new pools) —
        one jit dispatch serves any prefill/decode mix per engine tick.
        ``backend``/``kv_splits`` (static) pick the paged-attention kernel:
        'pallas' = fused page-gather flash-decode, 'ref' = jnp oracle,
        None/'auto' = pallas on TPU.
        """
        dense, sparse = _split_params(params)
        sp_layers = (sparse or {}).get("layers", {})
        sp_rem = (sparse or {}).get("rem", {})
        x = embed_inputs(dense, tokens)
        b, c = x.shape[0], x.shape[1]
        positions = start_pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None]

        def body(x, xs):
            layer_p, layer_c, layer_sp = xs
            x2, c2 = _super_paged(layer_p, x, cfg, layer_c, page_table,
                                  positions, n_tokens, layer_sp,
                                  attn_backend=backend, kv_splits=kv_splits)
            return x2, c2

        x, new_layer_pools = jax.lax.scan(
            body, x, (dense["layers"], pools["layers"], sp_layers))
        new_pools = {"layers": new_layer_pools}
        if rem:
            new_pools["rem"] = {}
            for i, kind in enumerate(rem):
                key = f"r{i}_{kind}"
                x, new_pools["rem"][key] = _apply_layer_paged(
                    dense["rem"][key], x, cfg, kind, pools["rem"][key],
                    page_table, positions, n_tokens, sp_rem.get(key),
                    attn_backend=backend, kv_splits=kv_splits)
        last = jnp.clip(n_tokens - 1, 0, c - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)   # (B, 1, d)
        return head(params, xl)[:, 0], new_pools

    paged_ok = all(k in ("attn", "rglru", "rwkv")
                   for k in cfg.block_pattern + rem)
    return Model(cfg=cfg, init=init, apply_train=apply_train,
                 apply_hidden=apply_hidden, head=head,
                 decode_step=decode_step, prefill=prefill,
                 init_cache=init_cache,
                 paged_step=paged_step if paged_ok else None)
