"""GQA attention with chunked (flash-style) streaming softmax, QK-norm,
sliding windows, RoPE, and a ring-buffer KV cache for decode.

The training/prefill path never materializes the (S, S) score matrix: it
streams over KV chunks with a running (max, sum, acc) — the pure-JAX flash
formulation. On TPU the same structure is what a Pallas flash kernel would
compute; keeping it in jnp lets XLA partition it with GSPMD and keeps the
dry-run honest about memory (see EXPERIMENTS.md §Perf for the block-skip
iteration).

Decode attention is a single-token product against the cache; for long
contexts the cache's sequence axis is sharded over the 'model' mesh axis
(sequence-parallel decode — softmax reductions become cross-chip collectives).

``prefill_attention`` consumes the whole prompt in one forward and fills the
ring KV cache in a single scatter — one jit dispatch replaces S stepwise
decodes. All three paths take an optional ``sparse`` dict of BlockCSR
projections ({"wq"|"wk"|"wv"|"wo": BlockCSR} in (out, in) layout), built by
``repro.sparse.compress.compress_params`` — the compressed serving runtime.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_ann
from repro.kernels.paged_attention import ops as paged_kops
from repro.models.layers import apply_norm, apply_rope, init_norm, truncated_normal_init
from repro.sparse import ops as sparse_ops

Array = jax.Array
NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal_init(ks[0], (d, h, hd), 1.0),
        "wk": truncated_normal_init(ks[1], (d, kv, hd), 1.0),
        "wv": truncated_normal_init(ks[2], (d, kv, hd), 1.0),
        "wo": truncated_normal_init(ks[3], (h, hd, d), 1.0),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, "rmsnorm")
        p["k_norm"] = init_norm(hd, "rmsnorm")
    return p


def _project_qkv(p: dict, x: Array, cfg: ModelConfig, positions: Array,
                 sparse: Optional[dict] = None):
    """QKV projections; entries of ``sparse`` ({"wq": BlockCSR, ...}, stored
    (heads*hd, d)) take the compressed-kernel path instead of the einsum."""
    dt = x.dtype
    b, s = x.shape[0], x.shape[1]
    hd = cfg.resolved_head_dim

    def proj(name, n_out_heads):
        if sparse and name in sparse:
            y = sparse_ops.sparse_matmul(x.reshape(-1, x.shape[-1]),
                                         sparse[name])
            return y.reshape(b, s, n_out_heads, hd).astype(dt)
        return jnp.einsum("bsd,dhk->bshk", x, p[name].astype(dt))

    q = proj("wq", cfg.n_heads)
    k = proj("wk", cfg.n_kv_heads)
    v = proj("wv", cfg.n_kv_heads)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # 'seq_fb' is a FALLBACK axis: it claims 'model' only when the head
    # count doesn't divide the mesh axis (e.g. smollm's 15 heads), turning
    # 16x-replicated attention into sequence-sharded attention (§Perf A1)
    q = shard_ann(q, ("batch", "seq_fb", "heads", "head_dim"))
    k = shard_ann(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_ann(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def chunked_attention(q: Array, k: Array, v: Array, *,
                      causal: bool = True,
                      window: Optional[int] = None,
                      q_chunk: int = 1024,
                      kv_chunk: int = 1024,
                      q_offset: int = 0,
                      seq_shard_fallback: bool = False) -> Array:
    """Streaming-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H a multiple of KV (GQA).
    Returns (B, Sq, H, hd). Never materializes (Sq, Skv).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nkv = sq // q_chunk, skv // kv_chunk
    assert sq % q_chunk == 0 and skv % kv_chunk == 0

    qg = q.reshape(b, nq, q_chunk, kv, g, hd)
    kg = k.reshape(b, nkv, kv_chunk, kv, hd)
    vg = v.reshape(b, nkv, kv_chunk, kv, hd)
    # q-dim sharding of the streaming softmax (scores/probs/acc get the
    # same q-sharding by propagation): the zero-communication layout when
    # heads can't shard — each device owns a q-token slice vs all KV.
    # ONLY applied on the fallback path: for heads-shardable archs this
    # constraint conflicts with the (kv x g) head tiling GSPMD derives from
    # the projections and forces a per-layer reshard storm (measured on
    # qwen3/command-r; EXPERIMENTS.md §Perf A-iterations).
    if seq_shard_fallback:
        qg = shard_ann(qg, ("batch", None, "seq_fb", "kv_heads", None,
                            "head_dim"))

    q_pos = (jnp.arange(sq) + q_offset).reshape(nq, q_chunk)
    k_pos = jnp.arange(skv).reshape(nkv, kv_chunk)

    def one_q_chunk(args):
        qc, qp = args                      # (b, q_chunk, kv, g, hd), (q_chunk,)

        def kv_step_inner(carry, xs):
            m, l, acc = carry
            kc, vc, kp = xs                # (b, kv_chunk, kv, hd), ..., (kv_chunk,)
            s = jnp.einsum("bqkgh,bckh->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = corr * l + jnp.sum(p, axis=-1)
            acc2 = corr[..., None] * acc + jnp.einsum(
                "bkgqc,bckh->bkgqh", p, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            return (m2, l2, acc2), None

        # flash-attention memory semantics: remat the kv-chunk body so the
        # (q_chunk, kv_chunk) score/prob tiles are NOT stacked as scan
        # residuals for backward — they are recomputed per chunk. Without
        # this, backward materializes the full (S, S) probabilities
        # (measured: ~9 GB/device residuals at 4k train; see EXPERIMENTS.md).
        kv_step = jax.checkpoint(
            kv_step_inner, policy=jax.checkpoint_policies.nothing_saveable)
        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kg.transpose(1, 0, 2, 3, 4), vg.transpose(1, 0, 2, 3, 4), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (b, q_chunk, kv, g, hd)

    outs = jax.lax.map(one_q_chunk, (qg.transpose(1, 0, 2, 3, 4, 5), q_pos))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def _heads_shardable(cfg: ModelConfig) -> bool:
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return True
    return cfg.n_heads % mesh.shape["model"] == 0


def _out_proj(p: dict, out: Array, dt, sparse: Optional[dict]) -> Array:
    """Output projection; sparse["wo"] is stored (d, heads*hd) BCSR."""
    if sparse and "wo" in sparse:
        b, s = out.shape[0], out.shape[1]
        y = sparse_ops.sparse_matmul(out.reshape(b * s, -1), sparse["wo"])
        return y.reshape(b, s, -1).astype(dt)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def apply_attention(p: dict, x: Array, cfg: ModelConfig,
                    positions: Array, sparse: Optional[dict] = None) -> Array:
    """Training / prefill self-attention over a full sequence."""
    # under the seq-parallel residual stream, attention is the only block
    # needing cross-token data: materialize full-seq ONCE here (one gather
    # per layer instead of GSPMD re-gathering at every projection). When
    # the head count cannot shard (seq_fb path), projections stay
    # seq-sharded and only K/V (a few heads) are gathered — skip the pin.
    shardable = _heads_shardable(cfg)
    if shardable:
        x = shard_ann(x, ("batch", "seq", "embed"))
    q, k, v = _project_qkv(p, x, cfg, positions, sparse)
    out = chunked_attention(q, k, v, causal=True, window=cfg.attn_window,
                            seq_shard_fallback=not shardable)
    y = _out_proj(p, out, x.dtype, sparse)
    return shard_ann(y, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> dict:
    """Ring-buffer cache: window-bounded for local attention.

    kv_cache_dtype='int8': k/v stored int8 with one f32 scale per
    (batch, slot, head) — halves cache HBM vs bf16 (the lever that brings
    the 104B 32k-decode cell under 16 GB/device on the single pod)."""
    size = seq_len if cfg.attn_window is None else min(cfg.attn_window, seq_len)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, size, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, size, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, size, kv, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, size, kv, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def _quantize_heads(x: Array):
    """Per-(batch, pos, head) symmetric int8 quantization."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention(p: dict, x: Array, cache: dict, pos: Array,
                     cfg: ModelConfig,
                     sparse: Optional[dict] = None) -> tuple[Array, dict]:
    """x: (B, 1, d); pos: scalar int32 position of the new token."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, sparse)

    size = cache["k"].shape[1]
    slot = pos % size
    new_cache = {}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_heads(k_new)
        vq, vs = _quantize_heads(v_new)
        upd = jax.lax.dynamic_update_slice
        new_cache["k"] = upd(cache["k"], kq, (0, slot, 0, 0))
        new_cache["v"] = upd(cache["v"], vq, (0, slot, 0, 0))
        new_cache["k_scale"] = upd(cache["k_scale"], ks, (0, slot, 0, 0))
        new_cache["v_scale"] = upd(cache["v_scale"], vs, (0, slot, 0, 0))
        k = (new_cache["k"].astype(jnp.float32) * new_cache["k_scale"]
             ).astype(x.dtype)
        v = (new_cache["v"].astype(jnp.float32) * new_cache["v_scale"]
             ).astype(x.dtype)
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        k, v = new_cache["k"], new_cache["v"]
    k = shard_ann(k, ("batch", "cache_seq", "kv_heads", "head_dim"))
    v = shard_ann(v, ("batch", "cache_seq", "kv_heads", "head_dim"))

    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qg, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5

    # validity of each ring slot at time `pos`
    idx = jnp.arange(size)
    written = jnp.where(pos + 1 >= size, size, pos + 1)
    valid = idx < written
    if cfg.attn_window is not None:
        # ring semantics: every surviving slot is within the window by
        # construction once the ring has wrapped
        age = (slot - idx) % size
        valid &= age < cfg.attn_window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)

    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", pattn, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = _out_proj(p, out, x.dtype, sparse)
    return shard_ann(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# Prefill (full prompt in one forward, cache populated in one write)
# ---------------------------------------------------------------------------

def _write_prefill_cache(cache: dict, k: Array, v: Array,
                         cfg: ModelConfig) -> dict:
    """Scatter the prompt's K/V into the ring cache in one shot.

    Slot for position p is ``p % size`` (decode_attention's ring rule). When
    the prompt is longer than the ring, only the last ``size`` positions
    survive — exactly what stepwise decode would have left behind.
    """
    size = cache["k"].shape[1]
    s = k.shape[1]
    n_keep = min(s, size)
    slots = (jnp.arange(n_keep) + s - n_keep) % size
    kk, vv = k[:, s - n_keep:], v[:, s - n_keep:]
    new = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_heads(kk)
        vq, vs = _quantize_heads(vv)
        new["k"] = cache["k"].at[:, slots].set(kq)
        new["v"] = cache["v"].at[:, slots].set(vq)
        new["k_scale"] = cache["k_scale"].at[:, slots].set(ks)
        new["v_scale"] = cache["v_scale"].at[:, slots].set(vs)
    else:
        new["k"] = cache["k"].at[:, slots].set(kk.astype(cache["k"].dtype))
        new["v"] = cache["v"].at[:, slots].set(vv.astype(cache["v"].dtype))
    return new


def prefill_attention(p: dict, x: Array, cache: dict, positions: Array,
                      cfg: ModelConfig,
                      sparse: Optional[dict] = None) -> tuple[Array, dict]:
    """Full-sequence attention over the prompt that also fills the KV cache.

    One chunked-attention forward replaces S single-token decode dispatches;
    returns (y, new_cache) with the cache ready for decode at pos = S.
    """
    shardable = _heads_shardable(cfg)
    if shardable:
        x = shard_ann(x, ("batch", "seq", "embed"))
    q, k, v = _project_qkv(p, x, cfg, positions, sparse)
    out = chunked_attention(q, k, v, causal=True, window=cfg.attn_window,
                            seq_shard_fallback=not shardable)
    y = _out_proj(p, out, x.dtype, sparse)
    new_cache = _write_prefill_cache(cache, k, v, cfg)
    return shard_ann(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# Paged attention (continuous-batching engine — serve/paged_kv.py)
# ---------------------------------------------------------------------------

def init_paged_kv(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype) -> dict:
    """Per-layer block-paged KV pool: K/V stored as (n_pages, page_size, kv,
    hd) pages shared by every request slot. Page 0 is the engine's trash
    page — never allocated to a request, so masked-out token writes can
    land there harmlessly. Slot-to-page ownership lives in the engine's
    page table, not here.

    kv_cache_dtype='int8': pages store int8 K/V plus one f32 scale per
    (page, offset, head) — the same static symmetric scheme as the ring
    cache (``_quantize_heads``), halving page-pool HBM."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((n_pages, page_size, kv, hd), jnp.int8),
            "v": jnp.zeros((n_pages, page_size, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((n_pages, page_size, kv, 1), jnp.float32),
            "v_scale": jnp.zeros((n_pages, page_size, kv, 1), jnp.float32),
        }
    return {"k": jnp.zeros((n_pages, page_size, kv, hd), dtype),
            "v": jnp.zeros((n_pages, page_size, kv, hd), dtype)}


def paged_attention(p: dict, x: Array, cache: dict, page_table: Array,
                    positions: Array, n_tokens: Array, cfg: ModelConfig,
                    sparse: Optional[dict] = None,
                    backend: Optional[str] = None,
                    kv_splits: int = 1) -> tuple[Array, dict]:
    """Mixed prefill/decode attention against a block-paged KV pool.

    x: (B, C, d) — B engine slots, up to C new tokens each; slot i carries
    ``n_tokens[i]`` valid tokens at absolute positions ``positions[i, :]``
    (decode slots have 1 valid token, prefill slots a chunk, inactive slots
    0). cache: {"k", "v"} (n_pages, page_size, kv, hd) pools; page_table:
    (B, P) physical page of each slot's logical page p (covering positions
    [p*page_size, (p+1)*page_size)), 0 for unallocated entries.

    The new K/V are scattered into each slot's pages first, then every
    query attends over its slot's pages under a causal-by-absolute-position
    mask — so one dispatch serves any mix of prefill chunks and
    single-token decodes (the engine's mixed step). Invalid queries read
    finite garbage that is discarded downstream; causality guarantees they
    never contaminate a valid position.

    ``backend`` dispatches the attention product (same semantics as
    ``sparse.ops.resolve_backend``): 'pallas' runs the fused page-gather
    flash-decode kernel (``kernels/paged_attention``) — the gathered
    ``(B, P*page_size, ...)`` context is never materialized, and
    ``kv_splits`` cuts the page walk into that many flash-decode lanes;
    'ref' keeps the gather-then-softmax jnp path below as the parity
    oracle; None/'auto' picks pallas on TPU, ref elsewhere.
    """
    b, c = x.shape[0], x.shape[1]
    ps = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, sparse)

    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n_tokens[:, None]
    logical = jnp.clip(positions // ps, 0, page_table.shape[1] - 1)
    phys = jnp.take_along_axis(page_table, logical, axis=1)     # (B, C)
    phys = jnp.where(valid, phys, 0)                            # trash page
    offs = positions % ps
    new_cache = {}
    if cfg.kv_cache_dtype == "int8":
        # Same static symmetric scheme as the ring cache: quantize the new
        # chunk per (slot, position, head), scatter codes + scales. The
        # attention product runs on a pool dequantized once per dispatch
        # (fusing the dequant into the page-gather kernel is future work).
        for name, new in (("k", k_new), ("v", v_new)):
            qn, sc = _quantize_heads(new)
            new_cache[name] = cache[name].at[
                phys.reshape(-1), offs.reshape(-1)].set(
                    qn.reshape(b * c, *qn.shape[2:]))
            new_cache[name + "_scale"] = cache[name + "_scale"].at[
                phys.reshape(-1), offs.reshape(-1)].set(
                    sc.reshape(b * c, *sc.shape[2:]))
        k_pool = (new_cache["k"].astype(jnp.float32)
                  * new_cache["k_scale"]).astype(x.dtype)
        v_pool = (new_cache["v"].astype(jnp.float32)
                  * new_cache["v_scale"]).astype(x.dtype)
    else:
        for name, new in (("k", k_new), ("v", v_new)):
            pool = cache[name]
            flat = new.reshape(b * c, *new.shape[2:]).astype(pool.dtype)
            new_cache[name] = pool.at[phys.reshape(-1),
                                      offs.reshape(-1)].set(flat)
        k_pool, v_pool = new_cache["k"], new_cache["v"]

    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    if sparse_ops.resolve_backend(backend or "auto") == "pallas":
        out = paged_kops.paged_flash_attention(
            q, k_pool, v_pool, page_table, positions,
            window=cfg.attn_window, kv_splits=kv_splits)
        out = out.astype(x.dtype)
        y = _out_proj(p, out, x.dtype, sparse)
        return shard_ann(y, ("batch", "seq", "embed")), new_cache

    P = page_table.shape[1]
    k_ctx = k_pool[page_table].reshape(b, P * ps, *k_new.shape[2:])
    v_ctx = v_pool[page_table].reshape(b, P * ps, *v_new.shape[2:])
    k_ctx = shard_ann(k_ctx, ("batch", "cache_seq", "kv_heads", "head_dim"))
    v_ctx = shard_ann(v_ctx, ("batch", "cache_seq", "kv_heads", "head_dim"))

    qg = q.reshape(b, c, kv, g, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k_ctx,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    k_pos = jnp.arange(P * ps, dtype=jnp.int32)
    mask = k_pos[None, None, :] <= positions[:, :, None]        # (B, C, K)
    if cfg.attn_window is not None:
        mask &= (positions[:, :, None] - k_pos[None, None, :]) < cfg.attn_window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)

    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bkgqh", pattn, v_ctx.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, c, h, hd).astype(x.dtype)
    y = _out_proj(p, out, x.dtype, sparse)
    return shard_ann(y, ("batch", "seq", "embed")), new_cache
