"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Time mixing (per head, dk = dv = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t ( S_{t-1} + diag(u) k_t^T v_t )

with token-shift ddlerp (data-dependent lerp via LoRA) producing r,k,v,g,w
inputs, and w_t = exp(-exp(tdecay_t)) per channel.

Train/prefill uses the **chunked parallel form** (the same schedule RWKV's
CUDA kernel and flash-linear-attention use): within a chunk of length L the
intra-chunk part is a masked (L, L) matmul — MXU-friendly — and the
inter-chunk part propagates the (dk, dv) state with a scan over chunks.
Decode is the O(1) recurrence. Chunk math is fp32 with clamped log-decay
(numerics note in the module test).

Channel mixing is the RWKV squared-ReLU FFN with token shift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_ann
from repro.models.layers import apply_proj, truncated_normal_init

Array = jax.Array
_LORA_R = 32
_CHUNK = 64
_CLAMP = 25.0      # max |cumulative log-decay| inside a chunk (exp(25)~7e10)


def _lora(key, d: int, out: int, r: int = _LORA_R) -> dict:
    k1, k2 = jax.random.split(key)
    return {"lora_a": truncated_normal_init(k1, (d, r), 1.0),
            "lora_b": jnp.zeros((r, out))}


def _apply_lora(p: dict, x: Array) -> Array:
    h = jnp.tanh(jnp.einsum("...d,dr->...r", x.astype(jnp.float32), p["lora_a"]))
    return jnp.einsum("...r,ro->...o", h, p["lora_b"])


def init_time_mix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    n_heads = d // cfg.rwkv_head_dim
    p = {
        "rwkv_r": truncated_normal_init(ks[0], (d, d), 1.0),
        "rwkv_k": truncated_normal_init(ks[1], (d, d), 1.0),
        "rwkv_v": truncated_normal_init(ks[2], (d, d), 1.0),
        "rwkv_g": truncated_normal_init(ks[3], (d, d), 1.0),
        "rwkv_o": truncated_normal_init(ks[4], (d, d), 1.0),
        "time_decay_base": jnp.linspace(-6.0, -1.0, d),   # tdecay init
        "time_first": jnp.linspace(0.1, 1.0, d),          # u ("bonus"),
        # per-channel (a constant init would mask dk/dv axis mix-ups)
        "mu": {name: 0.5 * jnp.ones((d,))
               for name in ("r", "k", "v", "g", "w")},
        "lora_w": _lora(ks[5], d, d),                     # ddlerp for decay
        "ln_x_scale": jnp.ones((d,)),                     # per-head groupnorm
    }
    return p


def init_channel_mix(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "cm_k": truncated_normal_init(ks[0], (d, ff), 2.0),
        "cm_v": truncated_normal_init(ks[1], (ff, d), 2.0),
        "cm_r": truncated_normal_init(ks[2], (d, d), 1.0),
        "mu_k": 0.5 * jnp.ones((d,)),
        "mu_r": 0.5 * jnp.ones((d,)),
    }


def _token_shift(x: Array, last: Array | None) -> Array:
    """x_{t-1} with the previous step's trailing token (decode) or zeros."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _heads(x: Array, hd: int) -> Array:
    b, s, d = x.shape
    return x.reshape(b, s, d // hd, hd)


def chunked_wkv(r, k, v, logw, u, state, chunk: int = _CHUNK):
    """Chunked linear-attention with per-channel data-dependent decay.

    r,k,v: (B, S, H, hd); logw: (B, S, H, hd) (<= 0); u: (H, hd);
    state: (B, H, hd, hd) or None. Returns (o, state').
    """
    b, s, h, hd = r.shape
    assert s % chunk == 0 or s < chunk, (s, chunk)
    chunk = min(chunk, s)
    n = s // chunk
    f32 = jnp.float32

    def split(x):
        return (x.astype(f32).reshape(b, n, chunk, h, hd)
                .transpose(1, 0, 3, 2, 4))          # (n, B, H, L, hd)

    rs, ks_, vs, lws = split(r), split(k), split(v), split(logw)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), f32)

    tri_strict = jnp.tril(jnp.ones((chunk, chunk), bool), -1)

    def body(S, xs):
        rc, kc, vc, lw = xs                          # (B, H, L, hd)
        cum = jnp.cumsum(lw, axis=2)                 # inclusive cumulative
        cum_prev = cum - lw                          # exclusive (up to t-1)
        total = cum[:, :, -1:, :]                    # (B, H, 1, hd)
        # inter-chunk: o_t += (r_t * exp(cum_prev)) @ S
        r_dec = rc * jnp.exp(cum_prev)
        o = jnp.einsum("bhld,bhdv->bhlv", r_dec, S)
        # intra-chunk: A[t,i] = sum_d r[t,d] k[i,d] exp(cum_prev[t]-cum[i]), i<t
        k_dec = kc * jnp.exp(jnp.clip(-cum, a_max=_CLAMP))
        att = jnp.einsum("bhld,bhmd->bhlm", r_dec, k_dec)
        att = jnp.where(tri_strict[None, None], att, 0.0)
        o = o + jnp.einsum("bhlm,bhmv->bhlv", att, vc)
        # current-token bonus: o_t += (r_t * u * k_t) . v_t
        bonus = jnp.sum(rc * u[None, :, None, :] * kc, axis=-1, keepdims=True)
        o = o + bonus * vc
        # state update: S' = diag(exp(total)) S + sum_i exp(total-cum_i) k_i v_i
        k_carry = kc * jnp.exp(jnp.clip(total - cum, a_max=_CLAMP))
        S2 = jnp.exp(total)[..., 0, :, None] * S + \
            jnp.einsum("bhld,bhlv->bhdv", k_carry, vc)
        return S2, o

    state, outs = jax.lax.scan(body, state, (rs, ks_, vs, lws))
    o = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)
    return o, state


def wkv_step(r, k, v, logw, u, state):
    """O(1) decode recurrence. r,k,v,logw: (B, 1, H, hd)."""
    f32 = jnp.float32
    rc, kc, vc = (x[:, 0].astype(f32) for x in (r, k, v))
    w = jnp.exp(logw[:, 0].astype(f32))              # (B, H, hd)
    kv = jnp.einsum("bhd,bhv->bhdv", kc, vc)
    # u ("bonus") weights the k index (dk), not dv
    o = jnp.einsum("bhd,bhdv->bhv", rc, state + u[None, :, :, None] * kv)
    state2 = w[..., None] * state + kv
    return o[:, None], state2


def wkv_scan(r, k, v, logw, u, state, valid):
    """Intra-chunk ``lax.scan`` of the O(1) recurrence with masked state
    advances — the engine's mixed-step path (serve/engine.py).

    r,k,v,logw: (B, C, H, hd); u: (H, hd); state: (B, H, hd, hd) f32;
    valid: (B, C) bool. The state advances only at valid positions, so a
    slot whose tick carries c < C tokens ends with exactly c updates
    applied (inactive slots keep their state bit-exactly). Positionwise
    math matches ``wkv_step`` (the decode oracle). Returns
    (o (B, C, H, hd) f32, state')."""
    f32 = jnp.float32

    def body(S, xs):
        rc, kc, vc, lw, vl = xs                      # (B, H, hd) x4, (B,)
        w = jnp.exp(lw)
        kv = jnp.einsum("bhd,bhv->bhdv", kc, vc)
        o = jnp.einsum("bhd,bhdv->bhv", rc, S + u[None, :, :, None] * kv)
        S2 = jnp.where(vl[:, None, None, None], w[..., None] * S + kv, S)
        return S2, o

    seq = tuple(x.astype(f32).transpose(1, 0, 2, 3)
                for x in (r, k, v, logw)) + (valid.T,)
    state, outs = jax.lax.scan(body, state, seq)
    return outs.transpose(1, 0, 2, 3), state


def _time_mix_inputs(p: dict, x: Array, cfg: ModelConfig, shift,
                     sparse: dict | None):
    """Token-shift ddlerp + r/k/v/g/decay projections shared by every
    time-mix entry point. Returns (rh, kh, vh, lwh, u, g) with r/k/v/logw
    already split into (B, S, H, hd) heads."""
    dt = x.dtype
    hd = cfg.rwkv_head_dim
    prev = _token_shift(x, shift)
    xx = (prev - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)

    def mix(name):
        return (x32 + xx * p["mu"][name]).astype(dt)

    xr, xk, xv, xg, xw = (mix(nm) for nm in ("r", "k", "v", "g", "w"))
    r = apply_proj(p, xr, "rwkv_r", sparse)
    k = apply_proj(p, xk, "rwkv_k", sparse)
    v = apply_proj(p, xv, "rwkv_v", sparse)
    g = jax.nn.silu(apply_proj(p, xg, "rwkv_g", sparse))

    tdecay = p["time_decay_base"] + _apply_lora(p["lora_w"], xw)
    logw = -jnp.exp(tdecay.astype(jnp.float32))       # (B, S, d), <= 0
    u = p["time_first"].reshape(-1, hd)               # (H, hd)

    rh, kh, vh = _heads(r, hd), _heads(k, hd), _heads(v, hd)
    lwh = _heads(logw, hd)
    rh = shard_ann(rh, ("batch", "seq", "rwkv_heads", "head_dim"))
    return rh, kh, vh, lwh, u, g


def _time_mix_output(p: dict, o: Array, g: Array, x: Array, hd: int,
                     sparse: dict | None) -> Array:
    """Per-head groupnorm (ln_x), silu gate, and output projection."""
    dt = x.dtype
    b, s = x.shape[0], x.shape[1]
    oh = o.reshape(b, s, -1, hd)
    mu = jnp.mean(oh, axis=-1, keepdims=True)
    var = jnp.var(oh, axis=-1, keepdims=True)
    o = ((oh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, -1)
    o = (o * p["ln_x_scale"]).astype(dt) * g
    y = apply_proj(p, o, "rwkv_o", sparse)
    return shard_ann(y, ("batch", "seq", "embed"))


def _shift_update(x: Array, n_tokens: Array, old: Array) -> Array:
    """New token-shift carry for the slot-pooled paths: the last VALID
    token's input per slot; slots with no tokens this tick keep theirs."""
    idx = jnp.clip(n_tokens - 1, 0, x.shape[1] - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return jnp.where((n_tokens > 0)[:, None], last.astype(jnp.float32), old)


def apply_time_mix(p: dict, x: Array, cfg: ModelConfig,
                   state: dict | None = None,
                   sparse: dict | None = None):
    """RWKV-6 time mixing. state = {"S": (B,H,hd,hd), "shift": (B,d)}.
    ``sparse``: optional {"rwkv_r"|...|"rwkv_o": BlockCSR} compressed
    projections (the r/k/v/g/o matmuls dispatch ``sparse_matmul``)."""
    hd = cfg.rwkv_head_dim
    rh, kh, vh, lwh, u, g = _time_mix_inputs(
        p, x, cfg, state["shift"] if state else None, sparse)

    if state is None:
        o, s_new = chunked_wkv(rh, kh, vh, lwh, u, None)
    else:
        o, s_new = wkv_step(rh, kh, vh, lwh, u, state["S"])

    o = o.reshape(x.shape[0], x.shape[1], -1)
    y = _time_mix_output(p, o, g, x, hd, sparse)
    new_state = {"S": s_new, "shift": x[:, -1].astype(jnp.float32)}
    return y, new_state


def apply_time_mix_paged(p: dict, x: Array, cfg: ModelConfig, state: dict,
                         n_tokens: Array, sparse: dict | None = None):
    """Slot-pooled RWKV-6 time mixing — the continuous-batching engine's
    mixed step (any mix of prefill chunks and single-token decodes).

    x: (B, C, d) — B engine slots, up to C new tokens each; slot i carries
    ``n_tokens[i]`` valid tokens (0 = inactive). state is the slot-indexed
    state pool {"S": (B,H,hd,hd), "shift": (B,d)}: the token-shift carry
    crosses chunk boundaries through ``state["shift"]``, and the WKV state
    advances through an intra-chunk ``lax.scan`` masked to each slot's
    valid positions (``wkv_scan``) — so chunked prefill equals the
    sequential recurrence and inactive slots keep their state bit-exactly.
    """
    hd = cfg.rwkv_head_dim
    valid = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :] \
        < n_tokens[:, None]
    rh, kh, vh, lwh, u, g = _time_mix_inputs(p, x, cfg, state["shift"],
                                             sparse)
    o, s_new = wkv_scan(rh, kh, vh, lwh, u, state["S"], valid)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    y = _time_mix_output(p, o, g, x, hd, sparse)
    return y, {"S": s_new, "shift": _shift_update(x, n_tokens,
                                                 state["shift"])}


def apply_channel_mix(p: dict, x: Array, state: dict | None = None,
                      sparse: dict | None = None):
    """RWKV FFN: sigmoid(W_r xr) * (W_v relu(W_k xk)^2). ``sparse``:
    optional {"cm_k"|"cm_v"|"cm_r": BlockCSR} compressed projections."""
    dt = x.dtype
    prev = _token_shift(x, state["shift"] if state else None)
    xx = (prev - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    xk = (x32 + xx * p["mu_k"]).astype(dt)
    xr = (x32 + xx * p["mu_r"]).astype(dt)
    k = apply_proj(p, xk, "cm_k", sparse)
    k = shard_ann(k, ("batch", "seq", "mlp"))
    kv = apply_proj(p, jnp.square(jax.nn.relu(k)), "cm_v", sparse)
    r = jax.nn.sigmoid(apply_proj(p, xr, "cm_r", sparse))
    y = r * kv
    y = shard_ann(y, ("batch", "seq", "embed"))
    return y, {"shift": x[:, -1].astype(jnp.float32)}


def apply_channel_mix_paged(p: dict, x: Array, state: dict, n_tokens: Array,
                            sparse: dict | None = None):
    """Slot-pooled channel mix: same positionwise math as
    ``apply_channel_mix`` (the FFN has no cross-token recurrence beyond the
    one-step token shift), but the shift carry advances to each slot's last
    VALID token — slots with no tokens this tick keep theirs."""
    y, _ = apply_channel_mix(p, x, state, sparse)
    return y, {"shift": _shift_update(x, n_tokens, state["shift"])}


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    return {
        "tm": {"S": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
               "shift": jnp.zeros((batch, d), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, d), jnp.float32)},
    }
