"""Top-k MoE layer with capacity-based dispatch, two implementations:

1. ``gspmd``: single-program dispatch (scatter into (E, C, d) buffers under
   sharding constraints, XLA chooses the collectives). Baseline; measured
   collective-bound on the production mesh — GSPMD lowers the token scatter
   to repeated (T*k, d) all-reduces (EXPERIMENTS.md §Perf B-iterations).

2. ``shard_map``: real expert parallelism. The batch is data-sharded and
   replicated over 'model'; each model column owns E/TP experts, locally
   selects + buffers the tokens routed to ITS experts (zero-communication
   dispatch), runs its expert FFNs, and one psum over 'model' combines the
   top-k contributions. FSDP-sharded expert weights are all-gathered once
   inside the region. This is the TPU-native analogue of switch-style
   all-to-all EP: because x is already replicated over the TP axis, the
   dispatch needs NO collective at all.

``apply_moe`` auto-selects: shard_map under a mesh whose 'model' axis
divides the expert count, gspmd otherwise (including meshless CPU tests).
Capacity semantics: gspmd enforces a global capacity; shard_map enforces a
per-data-shard capacity (what a real EP deployment does).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax moved shard_map out of experimental and (separately) renamed
# check_rep -> check_vma; support both, keying the kwarg on the actual
# signature rather than on where shard_map lives — the promotion and the
# rename did not happen in the same release.
import inspect as _inspect

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                           # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
_CHECK_REP_KW = ("check_vma" if "check_vma"
                 in _inspect.signature(_shard_map).parameters else "check_rep")

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import current_mesh, shard_ann
from repro.models.layers import activation, truncated_normal_init
from repro.sparse import ops as sparse_ops

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    e = cfg.moe
    d, ff = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": truncated_normal_init(ks[0], (d, e.n_experts), 1.0),
        "ewi": truncated_normal_init(ks[1], (e.n_experts, d, ff), 2.0),
        "ewg": truncated_normal_init(ks[2], (e.n_experts, d, ff), 2.0),
        "ewo": truncated_normal_init(ks[3], (e.n_experts, ff, d), 2.0),
    }
    if e.n_shared_experts:
        sff = ff * e.n_shared_experts
        p["shared"] = {
            "wi": truncated_normal_init(ks[4], (d, sff), 2.0),
            "wg": truncated_normal_init(ks[5], (d, sff), 2.0),
            "wo": truncated_normal_init(ks[6], (sff, d), 2.0),
        }
    return p


def _capacity(n_tokens: int, e: MoEConfig) -> int:
    c = int(e.capacity_factor * n_tokens * e.top_k / e.n_experts)
    return max(8, -(-c // 8) * 8)          # pad to 8 for TPU-friendly tiles


def apply_moe(p: dict, x: Array, cfg: ModelConfig,
              impl: str = "auto",
              sparse: dict | None = None) -> tuple[Array, dict]:
    """x: (B, S, d) -> (B, S, d), aux losses {load_balance, z_loss}.

    ``sparse`` maps expert projection names ({"ewi"|"ewg"|"ewo"}) to
    E-stacked BlockCSR/PaletteBCSR weights (per-expert (out, in) slices,
    built by ``sparse.compress.compress_params``); present entries run the
    compressed kernel path via a ``lax.map`` over experts. Compressed
    experts always take the single-program (gspmd) dispatch — under a mesh
    GSPMD partitions the mapped expert FFNs like any other scanned
    computation, while the shard_map EP path would need per-column BCSR
    re-chunking (open ROADMAP item)."""
    mesh = current_mesh()
    if sparse:
        if impl == "shard_map":
            raise ValueError("compressed (BCSR) experts serve through the "
                             "gspmd dispatch; shard_map EP does not support "
                             "sparse expert weights")
        return _apply_moe_gspmd(p, x, cfg, sparse)
    if impl == "auto":
        use_sm = (mesh is not None and "model" in mesh.shape
                  and cfg.moe.n_experts % mesh.shape["model"] == 0)
        impl = "shard_map" if use_sm else "gspmd"
    if impl == "shard_map":
        return _apply_moe_shard_map(p, x, cfg, mesh)
    return _apply_moe_gspmd(p, x, cfg)


def _shared_expert(p: dict, xt: Array, cfg: ModelConfig) -> Array:
    sp = p["shared"]
    dt = xt.dtype
    f = activation(cfg.act)
    hs = f(jnp.einsum("td,df->tf", xt, sp["wg"].astype(dt))) * \
        jnp.einsum("td,df->tf", xt, sp["wi"].astype(dt))
    return jnp.einsum("tf,fd->td", hs, sp["wo"].astype(dt))


def _router_and_aux(router_w, xt, e: MoEConfig):
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, e.top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e.n_experts), axis=0)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gate, expert_idx, me, ce, z


def _apply_moe_shard_map(p: dict, x: Array, cfg: ModelConfig,
                         mesh) -> tuple[Array, dict]:
    e = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    f = activation(cfg.act)
    tp = mesh.shape["model"]
    e_loc = e.n_experts // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    t_loc = (b // dp if b % dp == 0 else b) * s
    cap = _capacity(t_loc, e)

    def body(x_loc, router_w, ewi, ewg, ewo):
        bl = x_loc.shape[0]
        xt = x_loc.reshape(bl * s, d)
        gate, expert_idx, me, ce, z = _router_and_aux(router_w, xt, e)

        col = jax.lax.axis_index("model")
        # FSDP: gather this column's expert weights over 'data' (bf16)
        wi = jax.lax.all_gather(ewi.astype(dt), "data", axis=1, tiled=True)
        wg = jax.lax.all_gather(ewg.astype(dt), "data", axis=1, tiled=True)
        wo = jax.lax.all_gather(ewo.astype(dt), "data", axis=2, tiled=True)

        # local dispatch: only choices routed to THIS column's experts
        flat_e = expert_idx.reshape(-1)                      # (t*k,)
        flat_g = gate.reshape(-1)
        is_local = (flat_e // e_loc) == col
        le = jnp.where(is_local, flat_e % e_loc, e_loc)      # e_loc = trash
        eoh = jax.nn.one_hot(le, e_loc + 1, dtype=jnp.int32)
        pos = jnp.sum(jnp.cumsum(eoh, axis=0) * eoh, axis=-1) - 1
        keep = is_local & (pos < cap)
        slot = jnp.where(keep, pos, cap)
        le = jnp.where(keep, le, e_loc)

        buf = jnp.zeros((e_loc + 1, cap + 1, d), dt)
        tok_rep = jnp.repeat(jnp.arange(bl * s), e.top_k)
        buf = buf.at[le, slot].set(xt[tok_rep])
        buf = buf[:e_loc, :cap]

        h = f(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo)

        out_pad = jnp.concatenate(
            [out_buf, jnp.zeros((1, cap, d), dt)], axis=0)
        out_pad = jnp.concatenate(
            [out_pad, jnp.zeros((e_loc + 1, 1, d), dt)], axis=1)
        gathered = out_pad[le, slot]
        weighted = gathered * (flat_g * keep).astype(dt)[:, None]
        y = jax.ops.segment_sum(weighted, tok_rep, num_segments=bl * s)
        # combine top-k contributions across expert columns
        y = jax.lax.psum(y, "model")
        # aux stats: average over data shards (tokens), model-replicated
        me = jax.lax.pmean(me, batch_axes) if batch_axes else me
        ce = jax.lax.pmean(ce, batch_axes) if batch_axes else ce
        z = jax.lax.pmean(z, batch_axes) if batch_axes else z
        return y.reshape(bl, s, d), me, ce, z

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0]
                                                    if batch_axes else None)
    xspec = P(bspec if b % dp == 0 else None, None, None)
    y, me, ce, z = _shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(xspec, P(None), P(None), P()),
        **{_CHECK_REP_KW: False},
    )(x, p["router"], p["ewi"], p["ewg"], p["ewo"])

    aux = {"load_balance": e.n_experts * jnp.sum(me * ce),
           "z_loss": e.router_z_loss * z}
    if "shared" in p:
        xt = x.reshape(b * s, d)
        y = y + _shared_expert(p, xt, cfg).reshape(b, s, d)
    return shard_ann(y, ("batch", "seq", "embed")), aux


def _apply_moe_gspmd(p: dict, x: Array, cfg: ModelConfig,
                     sparse: dict | None = None) -> tuple[Array, dict]:
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = e.top_k
    cap = _capacity(t, e)
    dt = x.dtype
    f = activation(cfg.act)

    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)          # (t, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], e.n_experts)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = {
        "load_balance": e.n_experts * jnp.sum(me * ce),
        "z_loss": e.router_z_loss * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }

    # --- dispatch: position of each (token, choice) within its expert ------
    flat_e = expert_idx.reshape(-1)                      # (t*k,)
    eoh = jax.nn.one_hot(flat_e, e.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(eoh, axis=0) * eoh                  # running count
    pos_in_e = jnp.sum(pos, axis=-1) - 1                 # (t*k,)
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)                # cap = overflow bin

    buf = jnp.zeros((e.n_experts, cap + 1, d), dt)
    tok_rep = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[flat_e, slot].set(xt[tok_rep])
    buf = buf[:, :cap]
    buf = shard_ann(buf, ("experts", "capacity", "embed"))

    # --- expert FFN (grouped einsum, experts sharded over 'model') ---------
    # Compressed experts: lax.map slices the E-stacked BCSR (same mechanism
    # as the layer-stack scan) and runs sparse_matmul per expert — the
    # custom_vjp still applies, so SpC-Retrain's SDDMM weight gradient
    # reaches MoE expert data at resident slots only.
    def emm(name, inp):
        """(E, cap, in) x per-expert (in, out) -> (E, cap, out)."""
        if sparse and name in sparse:
            y = jax.lax.map(
                lambda wx: sparse_ops.sparse_matmul(wx[1], wx[0]),
                (sparse[name], inp))
            return y.astype(dt)
        return jnp.einsum("eci,eio->eco", inp, p[name].astype(dt))

    h = f(emm("ewg", buf)) * emm("ewi", buf)
    h = shard_ann(h, ("experts", "capacity", "mlp"))
    out_buf = emm("ewo", h)
    out_buf = shard_ann(out_buf, ("experts", "capacity", "embed"))

    # --- combine ------------------------------------------------------------
    out_pad = jnp.concatenate(
        [out_buf, jnp.zeros((e.n_experts, 1, d), dt)], axis=1)
    gathered = out_pad[flat_e, slot]                     # (t*k, d); dropped -> 0
    weighted = gathered * gate.reshape(-1, 1).astype(dt)
    y = jax.ops.segment_sum(weighted, tok_rep, num_segments=t)

    if "shared" in p:
        sp = p["shared"]
        hs = f(jnp.einsum("td,df->tf", xt, sp["wg"].astype(dt))) * \
            jnp.einsum("td,df->tf", xt, sp["wi"].astype(dt))
        y = y + jnp.einsum("tf,fd->td", hs, sp["wo"].astype(dt))

    y = y.reshape(b, s, d)
    return shard_ann(y, ("batch", "seq", "embed")), aux
