"""Unified telemetry: metrics registry, request tracer, profiling hooks.

Dependency-free observability for the serving + training stack:

* ``obs.metrics`` — live counters/gauges/histograms with labeled series,
  JSON snapshot + Prometheus text exposition, and the shared
  percentile/SLO helpers the stats dicts build on.
* ``obs.trace`` — per-request lifecycle + per-tick engine spans exported
  as Chrome trace-event / Perfetto JSON.
* ``obs.profile`` — ``block_until_ready``-bracketed wall timers around
  the jitted tick and the Pallas kernel entry points, plus the training
  telemetry JSONL stream.

Everything defaults off (``NULL_REGISTRY`` / ``NULL_TRACER`` / no active
profiler) and the disabled path is a no-op method call per site.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_REGISTRY, NullRegistry, parse_prometheus,
                               pct, prom_value, slo_summary)
from repro.obs.profile import (Profiler, TrainTelemetry, group_l1_penalty,
                               kernel_call, layer_block_sparsity,
                               sparsity_telemetry_fn, total_block_sparsity)
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer, \
    validate_chrome_trace

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "NULL_REGISTRY", "parse_prometheus", "pct", "prom_value", "slo_summary",
    "Profiler", "TrainTelemetry", "kernel_call", "group_l1_penalty",
    "layer_block_sparsity", "sparsity_telemetry_fn", "total_block_sparsity",
    "Tracer", "NullTracer", "NULL_TRACER", "validate_chrome_trace",
]
