"""Profiling hooks: kernel/tick wall timers + the training telemetry stream.

Three tools, all off by default and free when off:

* **``Profiler``** — ``block_until_ready``-bracketed wall timers. The
  engine wraps its jitted mixed step (``engine/tick_step``) and every
  Pallas kernel entry point routes through ``kernel_call(name, fn, ...)``:
  when a profiler is active and the call is *eager* (concrete arrays), the
  call is timed end-to-end including device sync; when the call happens
  inside a ``jit`` trace (arguments are tracers — wall time there is
  meaningless), only a traced-invocation count is recorded. When no
  profiler is active the hook is one module-global load and a ``None``
  check. ``jax_trace_dir`` additionally brackets the run with
  ``jax.profiler.start_trace``/``stop_trace`` for a full XLA timeline.
* **``TrainTelemetry``** — a per-step JSONL stream for the training loop:
  loss / grad-norm metrics, the group-l1 penalty, live per-layer block
  sparsity on the serving BCSR grid, and debias progress — the paper's
  compression-trajectory figure as replayable data
  (``launch/train --telemetry-out run.jsonl``).
* **Sparsity/penalty helpers** — ``layer_block_sparsity`` /
  ``group_l1_penalty`` measure a dense param tree on the exact (out, in)
  block grid ``sparse.compress`` serves from, so the telemetry stream
  reports the sparsity the compressed checkpoint will actually have.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Optional

import numpy as np

try:                                     # jax.core.Tracer moved across
    from jax.core import Tracer as _Tracer       # jax versions; tolerate both
except Exception:                                # pragma: no cover
    from jax._src.core import Tracer as _Tracer  # type: ignore

import jax

# the active profiler the kernel hooks consult — None = zero-overhead path
_ACTIVE: Optional["Profiler"] = None


def active() -> Optional["Profiler"]:
    return _ACTIVE


def kernel_call(name: str, fn: Callable, *args, **kwargs):
    """The kernel entry hook: ``ops.py`` wrappers route their jitted
    callable through this. Disabled cost: one global load + None check."""
    p = _ACTIVE
    if p is None:
        return fn(*args, **kwargs)
    return p.call(name, fn, *args, **kwargs)


class Profiler:
    """Wall-clock profiler for jitted entry points.

    Use as a context manager (``with Profiler() as p: ...; p.summary()``)
    or via explicit ``start()``/``stop()``. Only one profiler is active at
    a time (the kernel hooks consult a module global)."""

    def __init__(self, jax_trace_dir: Optional[str] = None):
        self.records: dict[str, dict] = {}
        self.jax_trace_dir = jax_trace_dir
        self._tracing = False

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Profiler":
        global _ACTIVE
        _ACTIVE = self
        if self.jax_trace_dir:
            try:
                jax.profiler.start_trace(self.jax_trace_dir)
                self._tracing = True
            except Exception:              # backend without profiler support
                self._tracing = False
        return self

    def stop(self) -> None:
        global _ACTIVE
        if _ACTIVE is self:
            _ACTIVE = None
        if self._tracing:
            try:
                jax.profiler.stop_trace()
            finally:
                self._tracing = False

    def __enter__(self) -> "Profiler":
        return self.start()

    def __exit__(self, *a) -> bool:
        self.stop()
        return False

    # -- measurement --------------------------------------------------------

    def _rec(self, name: str) -> dict:
        r = self.records.get(name)
        if r is None:
            r = self.records[name] = {"n_calls": 0, "total_ms": 0.0,
                                      "max_ms": 0.0, "n_traced": 0}
        return r

    def call(self, name: str, fn: Callable, *args, **kwargs):
        """Run ``fn(*args, **kwargs)``; eager calls are timed with a
        ``block_until_ready`` bracket, traced calls (inside jit) are only
        counted — a wall clock inside a trace measures tracing, not
        compute."""
        if any(isinstance(x, _Tracer)
               for x in jax.tree_util.tree_leaves((args, kwargs))):
            self._rec(name)["n_traced"] += 1
            return fn(*args, **kwargs)
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        dt_ms = (time.perf_counter() - t0) * 1e3
        r = self._rec(name)
        r["n_calls"] += 1
        r["total_ms"] += dt_ms
        if dt_ms > r["max_ms"]:
            r["max_ms"] = dt_ms
        return out

    def summary(self) -> dict:
        """``{name: {n_calls, total_ms, mean_ms, max_ms, n_traced}}``."""
        out = {}
        for name, r in self.records.items():
            out[name] = dict(r, mean_ms=(r["total_ms"] / r["n_calls"]
                                         if r["n_calls"] else 0.0))
        return out

    def format_summary(self) -> str:
        lines = ["profile (wall, block_until_ready-bracketed):"]
        for name, r in sorted(self.summary().items(),
                              key=lambda kv: -kv[1]["total_ms"]):
            lines.append(
                f"  {name:<28} {r['n_calls']:>6} calls "
                f"{r['total_ms']:>9.1f} ms total {r['mean_ms']:>8.3f} ms/call"
                + (f" ({r['n_traced']} traced)" if r["n_traced"] else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# training telemetry stream
# ---------------------------------------------------------------------------

class TrainTelemetry:
    """Append-only JSONL stream of training telemetry records.

    ``emit(record)`` writes one line and flushes — a crash loses at most
    the in-flight step, and the stream is tail-able while training runs."""

    def __init__(self, path: str):
        self.path = path
        self._f = None
        self.n_records = 0

    def emit(self, record: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "w")
        self._f.write(json.dumps(record, default=_json_default) + "\n")
        self._f.flush()
        self.n_records += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def _json_default(x):
    if isinstance(x, (np.floating, np.integer)):
        return x.item()
    if hasattr(x, "item") and getattr(x, "ndim", None) == 0:
        return x.item()
    return float(x)


# ---------------------------------------------------------------------------
# block-sparsity / group-l1 measurement on the serving grid
# ---------------------------------------------------------------------------

def _iter_target_mats(params):
    """Yield ``(path, 2D (out, in) float64 matrix)`` for every compressible
    weight, walking the same targets on the same orientation as
    ``sparse.compress`` (stack axes — scanned layers, MoE experts — are
    averaged by yielding each slice)."""
    from repro.sparse.compress import (_LAYER_TARGETS, _as_out_in,
                                       _lead_axes)

    def per_layer(layer, path, stacked):
        for sub, names in _LAYER_TARGETS.items():
            if sub not in layer:
                continue
            for name in names:
                if name not in layer[sub]:
                    continue
                arr = np.asarray(layer[sub][name])
                p = f"{path}/{sub}/{name}"
                lead = _lead_axes(name, stacked)
                mats = (arr.reshape((-1,) + arr.shape[lead:]) if lead
                        else arr[None])
                for mat in mats:
                    view = _as_out_in(p, mat)
                    if view is not None:
                        yield p, view.astype(np.float64)

    for lkey, layer in (params.get("layers") or {}).items():
        yield from per_layer(layer, f"layers/{lkey}", stacked=True)
    for lkey, layer in (params.get("rem") or {}).items():
        yield from per_layer(layer, f"rem/{lkey}", stacked=False)
    if "head" in params:
        view = _as_out_in("head", np.asarray(params["head"]))
        if view is not None:
            yield "head", view.astype(np.float64)


def _block_norms(mat: np.ndarray, block: tuple) -> np.ndarray:
    br, bc = block
    r, c = mat.shape
    mp = np.pad(mat, ((0, (-r) % br), (0, (-c) % bc)))
    R, C = mp.shape[0] // br, mp.shape[1] // bc
    blocks = mp.reshape(R, br, C, bc).transpose(0, 2, 1, 3)
    return np.sqrt((blocks ** 2).sum(axis=(2, 3)))


def layer_block_sparsity(params, block: tuple = (8, 64)) -> dict:
    """Per-layer fraction of exactly-zero (br, bc) blocks on the serving
    (out, in) grid — the live SpC trajectory. Stacked layers aggregate
    over the stack axis."""
    zero: dict[str, int] = {}
    total: dict[str, int] = {}
    for path, mat in _iter_target_mats(params):
        norms = _block_norms(mat, block)
        zero[path] = zero.get(path, 0) + int((norms == 0.0).sum())
        total[path] = total.get(path, 0) + int(norms.size)
    return {p: zero[p] / max(total[p], 1) for p in total}


def total_block_sparsity(params, block: tuple = (8, 64)) -> float:
    zero = tot = 0
    for path, mat in _iter_target_mats(params):
        norms = _block_norms(mat, block)
        zero += int((norms == 0.0).sum())
        tot += int(norms.size)
    return zero / max(tot, 1)


def group_l1_penalty(params, block: tuple = (8, 64),
                     lam: float = 1.0) -> float:
    """``lam * sum ||block||_2`` over the plan grid — the regularizer term
    the SpC prox descends on, measured on the live params."""
    total = 0.0
    for _, mat in _iter_target_mats(params):
        total += float(_block_norms(mat, block).sum())
    return lam * total


def sparsity_telemetry_fn(block: tuple, lam: float = 1.0):
    """An ``extra_fn`` for ``train_loop`` telemetry: total + per-layer
    block sparsity on the serving grid and the group-l1 penalty (at
    ``lam``) — the paper's compression trajectory, one record per log
    step."""
    def fn(params):
        return {"block_sparsity": total_block_sparsity(params, block),
                "group_l1_penalty": group_l1_penalty(params, block, lam),
                "layer_block_sparsity": layer_block_sparsity(params, block)}
    return fn
