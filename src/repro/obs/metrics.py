"""Dependency-free live metrics registry (counters / gauges / histograms).

The serving stack's visibility used to end at two ad-hoc end-of-run stats
dicts (``ServeEngine._stats``, ``Router.fleet_stats``). This module is the
single source of truth those dicts now read *from*: every load-bearing site
— scheduler admissions/preemptions/famine ticks, page-allocator occupancy
and free-list churn, prefix-cache hits/evictions/COW copies, per-tick token
budget utilization and compiled-width counts, router dispatch/backpressure/
failover, sampler batch sizes — increments a registry instrument instead of
a private counter, and the same numbers export as a JSON snapshot
(``MetricsRegistry.snapshot``) or Prometheus text exposition
(``MetricsRegistry.to_prometheus``).

Design constraints:

* **Dependency-free.** stdlib + numpy only (numpy is already a hard repo
  dependency); no prometheus_client, no opentelemetry.
* **Hot-path cheap.** An unlabeled ``Counter.inc()`` is one dict lookup +
  int add — the same cost as the private ``self.n_x += 1`` counters it
  replaces. Label resolution only happens on labeled instruments.
* **Zero-overhead off switch.** ``NULL_REGISTRY`` hands out a shared
  no-op instrument: every ``inc``/``set``/``observe`` is an empty method,
  ``value()`` reads 0, exports are empty. Components take a registry
  parameter and default to a live one (stats need real values), but the
  whole stack runs against ``NULL_REGISTRY`` — the overhead-guard tests
  hold the no-op path to noise.
* **Histograms are bounded.** Each series keeps exact count/sum/min/max
  plus a fixed-size reservoir of recent observations for percentile
  queries — a week-long serve run cannot grow the registry unboundedly.

Prometheus exposition notes: counters export as ``counter``, gauges as
``gauge``, histograms as the ``summary`` type (``{quantile="0.5"}`` /
``{quantile="0.95"}`` series from the reservoir plus exact ``_sum`` /
``_count``) — everything a text-format scraper accepts.
``parse_prometheus`` is the matching round-trip reader used by tests and
the CI smoke to assert the exposition actually parses.

The shared percentile/SLO helpers live here too (``pct``,
``slo_summary``) — previously duplicated between ``serve/engine.py`` and
``serve/router.py`` with an empty-list bug: percentiles of ``[]`` are
``None`` here, never a crash and never a fake ``0.0``.
"""
from __future__ import annotations

import json
import re
from typing import Iterable, Optional

import numpy as np

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


# ---------------------------------------------------------------------------
# shared percentile / SLO summary helpers (deduped from engine + router)
# ---------------------------------------------------------------------------

def pct(xs, q) -> Optional[float]:
    """Percentile ``q`` of ``xs`` — ``None`` for an empty sequence (an
    empty completions list must not crash ``np.percentile`` or report a
    fabricated 0.0 latency)."""
    xs = list(xs)
    if not xs:
        return None
    return float(np.percentile(xs, q))


def slo_summary(ttft: Iterable[float], latency: Iterable[float],
                n_requests: int, **extra) -> dict:
    """The SLO block shared by ``ServeEngine._stats`` and
    ``Router.fleet_stats``: p50/p95 TTFT + end-to-end latency (``None``
    when the record set is empty) plus caller-specific counters via
    ``extra`` (``n_preempted``, ``n_redispatched``, ...)."""
    ttft = list(ttft)
    latency = list(latency)
    return {
        "n_requests": int(n_requests),
        **extra,
        "ttft_p50_s": pct(ttft, 50), "ttft_p95_s": pct(ttft, 95),
        "latency_p50_s": pct(latency, 50), "latency_p95_s": pct(latency, 95),
    }


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

class _Metric:
    """Base: one named instrument holding labeled series. The series key is
    the tuple of label values in ``labelnames`` order; unlabeled
    instruments use the empty tuple (one dict lookup on the hot path)."""

    kind = ""

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict = {}

    def _key(self, labels: dict) -> tuple:
        if not self.labelnames:
            if labels:
                raise ValueError(f"{self.name} takes no labels, got {labels}")
            return ()
        try:
            return tuple(str(labels[n]) for n in self.labelnames)
        except KeyError as e:
            raise ValueError(f"{self.name} needs labels "
                             f"{self.labelnames}, got {tuple(labels)}") from e

    def series(self) -> list[tuple[dict, object]]:
        """[(labels dict, series state), ...] in insertion order."""
        return [(dict(zip(self.labelnames, k)), v)
                for k, v in self._series.items()]


class Counter(_Metric):
    """Monotonically increasing count."""

    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up ({amount})")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0) + amount

    def value(self, **labels) -> float:
        return self._series.get(self._key(labels), 0)

    def total(self) -> float:
        """Sum over every labeled series."""
        return sum(self._series.values())


class Gauge(_Metric):
    """Point-in-time value (set, or add signed deltas)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = value

    def add(self, delta: float, **labels) -> None:
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0) + delta

    def value(self, **labels) -> float:
        return self._series.get(self._key(labels), 0)


class _HistSeries:
    """Exact count/sum/min/max + a bounded ring of recent observations
    (percentiles are over the window — bounded memory by construction)."""

    __slots__ = ("count", "sum", "min", "max", "samples", "_i", "_cap")

    def __init__(self, cap: int):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.samples: list[float] = []
        self._i = 0
        self._cap = cap

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        if len(self.samples) < self._cap:
            self.samples.append(v)
        else:                              # ring: overwrite oldest
            self.samples[self._i] = v
            self._i = (self._i + 1) % self._cap


class Histogram(_Metric):
    """Value distribution: exact count/sum/min/max, windowed percentiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labelnames: tuple = (),
                 max_samples: int = 4096):
        super().__init__(name, help, labelnames)
        self.max_samples = int(max_samples)

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = _HistSeries(self.max_samples)
        s.observe(float(value))

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return s.count if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return s.sum if s else 0.0

    def percentile(self, q: float, **labels) -> Optional[float]:
        s = self._series.get(self._key(labels))
        return pct(s.samples, q) if s else None


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Get-or-create registry: the same (name, kind, labelnames) always
    resolves to the same instrument, so every component can bind its
    instruments at construction and share the registry freely."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, labelnames: tuple, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {cls.kind} "
                    f"labels={tuple(labelnames)} but exists as {m.kind} "
                    f"labels={m.labelnames}")
            return m
        m = cls(name, help, tuple(labelnames), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  max_samples: int = 4096) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         max_samples=max_samples)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe dump: ``{name: {"type", "help", "series": [...]}}``.
        Histogram series carry count/sum/min/max/p50/p95/p99."""
        out = {}
        for name, m in self._metrics.items():
            rows = []
            for labels, s in m.series():
                if m.kind == "histogram":
                    rows.append({"labels": labels, "count": s.count,
                                 "sum": s.sum, "min": s.min, "max": s.max,
                                 "p50": pct(s.samples, 50),
                                 "p95": pct(s.samples, 95),
                                 "p99": pct(s.samples, 99)})
                else:
                    rows.append({"labels": labels, "value": s})
            out[name] = {"type": m.kind, "help": m.help, "series": rows}
        return out

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
            f.write("\n")

    def to_prometheus(self, extra_labels: Optional[dict] = None) -> str:
        """Prometheus text exposition (0.0.4). ``extra_labels`` are merged
        into every series — the router exports N replica registries into
        one page with ``{"replica": i}``."""
        extra = dict(extra_labels or {})
        lines: list[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {_esc_help(m.help)}")
            kind = "summary" if m.kind == "histogram" else m.kind
            lines.append(f"# TYPE {name} {kind}")
            for labels, s in m.series():
                merged = {**extra, **labels}
                if m.kind == "histogram":
                    for q in (0.5, 0.95, 0.99):
                        v = pct(s.samples, q * 100)
                        if v is not None:
                            lines.append(_sample(
                                name, {**merged, "quantile": str(q)}, v))
                    lines.append(_sample(f"{name}_sum", merged, s.sum))
                    lines.append(_sample(f"{name}_count", merged, s.count))
                else:
                    lines.append(_sample(name, merged, s))
        return "\n".join(lines) + "\n"

    def save_prometheus(self, path: str,
                        extra_labels: Optional[dict] = None) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus(extra_labels))


def _esc_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _esc_label(s: str) -> str:
    return (s.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _sample(name: str, labels: dict, value) -> str:
    label_s = ""
    if labels:
        inner = ",".join(f'{k}="{_esc_label(str(v))}"'
                         for k, v in labels.items())
        label_s = "{" + inner + "}"
    if value is None:
        value = float("nan")
    return f"{name}{label_s} {float(value):g}"


# ---------------------------------------------------------------------------
# the no-op registry (the disabled path must cost nothing)
# ---------------------------------------------------------------------------

class _NullMetric:
    """Accepts every instrument call, stores nothing, reads as empty."""

    def inc(self, *a, **k):
        pass

    def set(self, *a, **k):
        pass

    def add(self, *a, **k):
        pass

    def observe(self, *a, **k):
        pass

    def value(self, *a, **k):
        return 0

    def total(self):
        return 0

    def count(self, *a, **k):
        return 0

    def sum(self, *a, **k):
        return 0.0

    def percentile(self, *a, **k):
        return None


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Shared no-op: every instrument is the same ``_NullMetric``, exports
    are empty. Pass ``NULL_REGISTRY`` to strip telemetry entirely (stats
    counters then read 0 — the stats *structure* still works)."""

    def counter(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, *a, **k) -> _NullMetric:
        return _NULL_METRIC

    def get(self, name):
        return None

    def snapshot(self) -> dict:
        return {}

    def to_prometheus(self, extra_labels=None) -> str:
        return ""

    def save_json(self, path: str) -> None:
        pass

    def save_prometheus(self, path: str, extra_labels=None) -> None:
        pass


NULL_REGISTRY = NullRegistry()


# ---------------------------------------------------------------------------
# exposition round-trip (tests + CI smoke)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict:
    """Strict-enough text-format reader: returns
    ``{(name, (("label", "value"), ...)): float}``. Raises ``ValueError``
    on any line that is neither a comment nor a valid sample — the CI
    smoke's 'the exposition actually parses' assertion."""
    out: dict = {}
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {ln}: not a prometheus sample: {line!r}")
        labels = tuple(sorted(
            (k, v.replace('\\"', '"').replace("\\\\", "\\"))
            for k, v in _LABEL_PAIR_RE.findall(m.group("labels") or "")))
        out[(m.group("name"), labels)] = float(m.group("value"))
    return out


def prom_value(parsed: dict, name: str, **labels) -> Optional[float]:
    """Sum of every parsed series of ``name`` matching the given label
    subset (label-free query sums the whole family)."""
    want = {k: str(v) for k, v in labels.items()}
    total, seen = 0.0, False
    for (n, lab), v in parsed.items():
        if n != name:
            continue
        lab = dict(lab)
        if all(lab.get(k) == s for k, s in want.items()):
            total += v
            seen = True
    return total if seen else None
