"""Request lifecycle tracer — a serve run as an openable timeline.

Emits Chrome trace-event JSON (the Perfetto / ``chrome://tracing`` on-disk
format): load the exported file in https://ui.perfetto.dev and every
request is a track showing exactly where its latency went.

Model:

* **One synthetic thread per request** (tid = submission order + 1), plus
  tid 0 for the engine itself. Thread-name metadata events label them
  ``request <rid>`` / ``engine``.
* **Lifecycle phases as complete ("X") spans** on the request's track:
  ``wait`` (submit -> admit, and again after every preemption, with
  ``resumed: true``), ``prefill`` (admit -> prompt done; ``prefill_chunk``
  instants mark each scheduled chunk), ``decode`` (first sample -> done).
  Exactly one phase is open per request at any time — ``phase()`` closes
  the previous span, so a preempt-requeue produces a *resumed* span chain,
  never an overlapping duplicate.
* **Instant ("i") markers** for the point events: ``submit``,
  ``first_token``, ``preempt``, ``done``.
* **Per-tick engine spans** on tid 0: each ``tick`` span nests a
  ``schedule`` (host-side planning) and ``step`` (jitted mixed step) child
  — Perfetto nests same-track spans by containment.

Timestamps are ``time.perf_counter`` microseconds relative to tracer
construction; the engine stamps scheduler events with the same clock, so
trace span durations reconcile with the stats dict's ttft/latency numbers
(tested to within a tick).

``NullTracer`` (shared ``NULL_TRACER``) is the disabled path: every hook
is an empty method and ``span()`` hands back one reusable no-op context
manager — tracing off costs a method call per site, nothing more.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Optional

ENGINE_TID = 0

# chrome trace-event keys every exported event must carry (the schema the
# tests validate against)
REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


class Tracer:
    """Collects events in memory; ``to_chrome()`` / ``save()`` export."""

    enabled = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.events: list[dict] = []
        self._tids: dict[int, int] = {}        # rid -> tid
        self._open_phase: dict[int, tuple] = {}  # rid -> (name, t0_us, args)
        self._next_tid = 1

    # -- clock --------------------------------------------------------------

    def now_us(self) -> int:
        return int((self._clock() - self._t0) * 1e6)

    def _tid(self, rid: int) -> int:
        tid = self._tids.get(rid)
        if tid is None:
            tid = self._tids[rid] = self._next_tid
            self._next_tid += 1
        return tid

    # -- raw event emitters -------------------------------------------------

    def _complete(self, name: str, cat: str, tid: int, ts: int, dur: int,
                  args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "X", "ts": ts,
              "dur": max(int(dur), 1), "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, tid: int = ENGINE_TID, cat: str = "engine",
                **args) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self.now_us(), "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, tid: int = ENGINE_TID, cat: str = "engine",
             **args):
        """Engine-side timed span (tick / schedule / step)."""
        t0 = self.now_us()
        mutable = dict(args)
        try:
            yield mutable                    # caller may add result args
        finally:
            self._complete(name, cat, tid, t0, self.now_us() - t0, mutable)

    def complete_span(self, name: str, t0_us: int, tid: int = ENGINE_TID,
                      cat: str = "engine", **args) -> None:
        """Close a span opened by hand at ``t0_us = tracer.now_us()`` —
        for spans whose begin/end straddle an early-return (the engine's
        ``tick`` span, which is only emitted for non-idle ticks)."""
        self._complete(name, cat, tid, t0_us, self.now_us() - t0_us, args)

    # -- request lifecycle --------------------------------------------------

    def phase(self, rid: int, name: str, **args) -> None:
        """Switch request ``rid`` to lifecycle phase ``name``: closes the
        open phase span (if any) and opens the new one. No-op when already
        in that phase — per-token callers don't need their own edge
        detection."""
        tid = self._tid(rid)
        open_ = self._open_phase.get(rid)
        now = self.now_us()
        if open_ is not None:
            if open_[0] == name:
                return
            oname, t0, oargs = open_
            self._complete(oname, "request", tid, t0, now - t0, oargs)
        self._open_phase[rid] = (name, now, {"rid": rid, **args})

    def end_phases(self, rid: int) -> None:
        """Close the open phase (request finished)."""
        open_ = self._open_phase.pop(rid, None)
        if open_ is not None:
            name, t0, args = open_
            self._complete(name, "request", self._tid(rid), t0,
                           self.now_us() - t0, args)

    def request_submit(self, rid: int, priority: int, n_prompt: int) -> None:
        tid = self._tid(rid)
        self.instant("submit", tid=tid, cat="request", rid=rid,
                     priority=priority, n_prompt=n_prompt)
        self.phase(rid, "wait", priority=priority)

    def request_admit(self, rid: int, resumed: bool, n_cached: int) -> None:
        self.phase(rid, "prefill", resumed=resumed, n_cached=n_cached)

    def request_prefill_chunk(self, rid: int, n_tokens: int) -> None:
        self.instant("prefill_chunk", tid=self._tid(rid), cat="request",
                     rid=rid, n_tokens=n_tokens)

    def request_first_token(self, rid: int) -> None:
        self.instant("first_token", tid=self._tid(rid), cat="request",
                     rid=rid)

    def request_decode(self, rid: int) -> None:
        self.phase(rid, "decode")

    def request_preempt(self, rid: int) -> None:
        self.instant("preempt", tid=self._tid(rid), cat="request", rid=rid)
        self.phase(rid, "wait", resumed=True)

    def request_finish(self, rid: int) -> None:
        self.end_phases(rid)
        self.instant("done", tid=self._tid(rid), cat="request", rid=rid)

    # -- export -------------------------------------------------------------

    def to_chrome(self, process_name: str = "serve-engine") -> dict:
        """``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with process/
        thread-name metadata and events sorted by (ts, tid) — the monotonic
        order Perfetto and the schema tests expect."""
        meta = [{"name": "process_name", "ph": "M", "ts": 0, "pid": 0,
                 "tid": 0, "args": {"name": process_name}},
                {"name": "thread_name", "ph": "M", "ts": 0, "pid": 0,
                 "tid": ENGINE_TID, "args": {"name": "engine"}}]
        for rid, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                         "pid": 0, "tid": tid,
                         "args": {"name": f"request {rid}"}})
        # sort by ts; at equal ts the longer (parent) span comes first so
        # nesting renders deterministically
        body = sorted(self.events,
                      key=lambda e: (e["ts"], e["tid"], -e.get("dur", 0)))
        return {"traceEvents": meta + body, "displayTimeUnit": "ms"}

    def save(self, path: str, process_name: str = "serve-engine") -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(process_name), f)
            f.write("\n")


class _NullCtx:
    def __enter__(self):
        return {}

    def __exit__(self, *a):
        return False


_NULL_CTX = _NullCtx()


class NullTracer:
    """The disabled tracer: every hook is a no-op (explicit methods — a
    typo'd hook name fails loudly instead of silently no-opping)."""

    enabled = False
    events: list = []

    def now_us(self) -> int:
        return 0

    def instant(self, *a, **k):
        pass

    def span(self, *a, **k):
        return _NULL_CTX

    def complete_span(self, *a, **k):
        pass

    def phase(self, *a, **k):
        pass

    def end_phases(self, *a, **k):
        pass

    def request_submit(self, *a, **k):
        pass

    def request_admit(self, *a, **k):
        pass

    def request_prefill_chunk(self, *a, **k):
        pass

    def request_first_token(self, *a, **k):
        pass

    def request_decode(self, *a, **k):
        pass

    def request_preempt(self, *a, **k):
        pass

    def request_finish(self, *a, **k):
        pass

    def to_chrome(self, process_name: str = "serve-engine") -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str, process_name: str = "serve-engine") -> None:
        pass


NULL_TRACER = NullTracer()


def validate_chrome_trace(doc: dict) -> list[dict]:
    """Schema check used by tests and the CI smoke: the document is a
    trace-event JSON object whose events all carry the required keys, "X"
    events carry ``dur``, and non-metadata timestamps are sorted. Returns
    the event list; raises ``ValueError`` on any violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a chrome trace: missing 'traceEvents'")
    events = doc["traceEvents"]
    last_ts = None
    for i, ev in enumerate(events):
        for k in REQUIRED_KEYS:
            if k not in ev:
                raise ValueError(f"event {i} missing required key {k!r}: "
                                 f"{ev}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {i} missing 'dur': {ev}")
        if ev["ph"] == "M":
            continue
        if last_ts is not None and ev["ts"] < last_ts:
            raise ValueError(f"event {i} breaks ts monotonicity: "
                             f"{ev['ts']} < {last_ts}")
        last_ts = ev["ts"]
    return events
