"""olmoe-1b-7b — 64-expert top-8 MoE. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,                 # per-expert FFN width
    vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    qk_norm=True,              # OLMoE uses QK-norm
    norm="rmsnorm",
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
    source="arXiv:2409.02060; hf",
)
