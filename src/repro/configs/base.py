"""Model/config dataclasses for the architecture zoo.

Every assigned architecture is a ``ModelConfig`` (src/repro/configs/<id>.py).
``reduced()`` derives the CPU smoke-test config of the same family (few
layers, narrow width, tiny vocab) per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    router_z_loss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    block_pattern: tuple = ("attn",)   # repeated to n_layers
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    attn_window: Optional[int] = None  # sliding-window size (local attention)
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    frontend: str = "none"             # none | vlm | audio (stubs)
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    mlp_gated: bool = True
    act: str = "silu"                  # silu | gelu | relu2
    logit_softcap: Optional[float] = None
    lru_width: Optional[int] = None    # RG-LRU recurrence width
    conv1d_width: int = 4              # RG-LRU temporal conv
    rwkv_head_dim: int = 64
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = "compute"    # "compute" | "int8" (quantized cache)
    source: str = ""                   # provenance tag from the assignment

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_super_blocks(self) -> int:
        """Full pattern repeats (scanned); remainder layers are unrolled."""
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_pattern(self) -> tuple:
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    @property
    def sub_quadratic(self) -> bool:
        """Supports long_500k decode (O(1)-ish per-token state)."""
        return all(b != "attn" or self.attn_window is not None
                   for b in self.block_pattern)

    @property
    def attn_free(self) -> bool:
        return all(b in ("rwkv",) for b in self.block_pattern)

    def _layer_params(self, blk: str) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        n = 0
        if blk == "attn":
            n += d * (self.n_heads + 2 * self.n_kv_heads) * hd
            n += self.n_heads * hd * d
            n += 2 * d                          # norms
        elif blk == "rglru":
            w = self.lru_width or d
            n += d * w * 2 + w * d              # in (x2 branch), out
            n += w * self.conv1d_width          # temporal conv
            n += 3 * w                          # a-param, input gate, rec gate
            n += 2 * d
        elif blk == "rwkv":
            n += 5 * d * d                      # r,k,v,g,o (time mix)
            n += d * 32 * 5 * 2                 # ddlerp LoRAs (approx)
            n += 2 * d
        if self.moe is not None:
            e = self.moe
            n += d * e.n_experts
            n += e.n_experts * 3 * d * e.d_ff_expert
            n += e.n_shared_experts * 3 * d * e.d_ff_expert
        elif blk == "rwkv":
            n += 2 * d * ff + d * d             # rwkv channel mix
        else:
            mult = 3 if self.mlp_gated else 2
            n += mult * d * ff
        return n

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        total = self.vocab * d                   # embedding
        if not self.tie_embeddings:
            total += d * self.vocab
        pat = self.block_pattern
        total += sum(self._layer_params(pat[i % len(pat)])
                     for i in range(self.n_layers))
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        dense_expert = e.n_experts * 3 * self.d_model * e.d_ff_expert
        active_expert = (e.top_k + e.n_shared_experts) * 3 * self.d_model * e.d_ff_expert
        return self.n_params() - (dense_expert - active_expert) * self.n_layers

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test config of the same family."""
        pat = self.block_pattern
        layers = len(pat) * max(1, 2 // len(pat))   # 1-2 pattern repeats
        if self.n_layers % len(pat):
            layers += self.n_layers % len(pat)      # keep remainder-path coverage
        heads = min(self.n_heads, 4) if self.n_heads else 0
        kv = max(1, min(self.n_kv_heads, heads)) if heads else 0
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, n_experts=4,
                                      top_k=min(self.moe.top_k, 2),
                                      d_ff_expert=64)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=layers,
            d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
            d_ff=128, vocab=128, moe=moe,
            lru_width=64 if self.lru_width else None,
            attn_window=min(self.attn_window, 16) if self.attn_window else None,
            compute_dtype="float32")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
