"""qwen3-0.6b — dense GQA with per-head QK-RMSNorm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,              # Qwen3 uses head_dim 128 (> d_model/n_heads)
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    norm="rmsnorm",
    mlp_gated=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
)
