"""rwkv6-3b (Finch) — attention-free, data-dependent decay linear RNN.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,          # 2560 / 64 = 40 wkv heads
    norm="layernorm",
    mlp_gated=False,
    act="relu2",               # RWKV channel-mix uses squared ReLU
    tie_embeddings=False,
    source="arXiv:2404.05892; hf",
)
