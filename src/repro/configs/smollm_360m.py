"""smollm-360m — llama-arch small dense GQA. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    norm="rmsnorm",
    mlp_gated=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=10000.0,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)
