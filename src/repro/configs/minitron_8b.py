"""minitron-8b — pruned Nemotron dense GQA transformer. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    norm="layernorm",
    mlp_gated=False,           # Nemotron family: squared-ReLU non-gated MLP
    act="relu2",
    tie_embeddings=False,
    rope_theta=10000.0,
    source="arXiv:2407.14679; hf",
)
