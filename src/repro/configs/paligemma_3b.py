"""paligemma-3b — SigLIP + Gemma VLM; backbone only, SigLIP patch embeddings
arrive precomputed via the stub frontend. [arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    frontend="vlm",
    norm="rmsnorm",
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2407.07726; hf",
)
