"""musicgen-medium — decoder-only over EnCodec tokens; the EnCodec frontend is
a stub providing precomputed frame embeddings. [arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,             # full MHA
    head_dim=64,
    d_ff=6144,
    vocab=2048,                # EnCodec codebook size
    frontend="audio",
    norm="layernorm",
    mlp_gated=False,           # MusicGen uses standard GELU MLP
    act="gelu",
    tie_embeddings=False,
    rope_theta=10000.0,
    source="arXiv:2306.05284; hf",
)
