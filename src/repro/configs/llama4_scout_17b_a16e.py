"""llama4-scout-17b-a16e — 16-expert top-1 MoE with shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared_experts=1),
    norm="rmsnorm",
    mlp_gated=True,
    act="silu",
    tie_embeddings=False,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
