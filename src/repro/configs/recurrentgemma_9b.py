"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,                       # 12 full (rglru, rglru, attn) patterns
    n_heads=16,                        # + 2 remainder rglru layers (the stack
    n_kv_heads=1,                      # scans the 12 patterns and unrolls the
    d_model=4096,                      # remainder; see models/transformer.py)
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "attn"),
    attn_window=2048,                  # local attention window
    lru_width=4096,
    conv1d_width=4,
    norm="rmsnorm",
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2402.19427; unverified",
)
