"""Architecture config registry.

``get_config(name)`` resolves any assigned architecture id (plus the paper's
own four CNNs, which live in models/cnn.py and are config'd by name only).
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, MoEConfig, ShapeConfig  # noqa: F401

ARCH_IDS = [
    "command-r-plus-104b",
    "minitron-8b",
    "smollm-360m",
    "qwen3-0.6b",
    "olmoe-1b-7b",
    "llama4-scout-17b-a16e",
    "recurrentgemma-9b",
    "paligemma-3b",
    "musicgen-medium",
    "rwkv6-3b",
]

PAPER_CNN_IDS = ["lenet5", "alexnet-cifar", "vgg16-cifar", "resnet32-cifar"]

_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "minitron-8b": "minitron_8b",
    "smollm-360m": "smollm_360m",
    "qwen3-0.6b": "qwen3_0_6b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-medium": "musicgen_medium",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shapes_for(cfg: ModelConfig) -> list[str]:
    """Runnable shape cells for an arch (long_500k needs sub-quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
