"""command-r-plus-104b — dense GQA transformer, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    norm="layernorm",          # Cohere uses LayerNorm (no bias)
    mlp_gated=True,
    act="silu",
    tie_embeddings=True,       # Cohere ties input/output embeddings
    rope_theta=75_000_000.0,
    kv_cache_dtype="int8",     # 550 GB bf16 cache at decode_32k -> int8
                               # halves it (fits 16 GB/dev on one pod)
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
