"""'With flash kernel' roofline accounting (§Perf iteration K1).

The Pallas flash-attention kernel cannot be compiled by the CPU backend, so
the dry-run artifact keeps the streaming-jnp attention. Its effect on the
roofline is computed *measurably*, not hand-waved:

 1. the attention interior (everything between the qkv projections and the
    output projection) is lowered STANDALONE at the cell's exact per-device
    local shapes and costed with the same trip-count-corrected HLO parser
    (fwd for prefill; fwd + vjp + remat-recompute for train),
 2. interior bytes are replaced by the kernel's HBM I/O (q/k/v/o and their
    gradients — 6 h-sized + 6 kv-sized array passes), which is what a
    VMEM-resident kernel actually moves,
 3. interior matmul FLOPs are scaled by the causal block-skip factor
    (~0.5 + diagonal) the kernel's @pl.when skip realizes.

The adjusted three terms are reported alongside the baseline in
EXPERIMENTS.md §Roofline; the kernel itself is validated against its oracle
in tests/test_kernels_flash.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.attention import chunked_attention
from repro.roofline.analysis import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo_cost import module_cost


def _attn_layers(cfg: ModelConfig) -> int:
    pat = cfg.block_pattern
    per = sum(1 for b in pat if b == "attn")
    n = per * cfg.n_super_blocks
    n += sum(1 for b in cfg.remainder_pattern if b == "attn")
    return n


def _local_shapes(cfg: ModelConfig, shape: ShapeConfig, mb: int,
                  dp: int, tp: int):
    b_loc = max(shape.global_batch // mb // dp, 1)
    if cfg.n_heads % tp == 0:
        h_loc, s_q = cfg.n_heads // tp, shape.seq_len
    else:
        # seq_fb fallback path: heads replicated, q-dim sharded
        h_loc = cfg.n_heads
        s_q = shape.seq_len // tp if shape.seq_len % tp == 0 else shape.seq_len
    kv_loc = max(cfg.n_kv_heads // tp, 1) if cfg.n_kv_heads % tp == 0 \
        else cfg.n_kv_heads
    # GQA grouping must stay integral locally
    g = max(h_loc // kv_loc, 1)
    kv_loc = h_loc // g
    return b_loc, s_q, h_loc, kv_loc


def _interior_cost(cfg, b_loc, s_q, s_kv, h_loc, kv_loc, train: bool):
    hd = cfg.resolved_head_dim
    q = jax.ShapeDtypeStruct((b_loc, s_q, h_loc, hd), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((b_loc, s_kv, kv_loc, hd), jnp.bfloat16)
    v = jax.ShapeDtypeStruct((b_loc, s_kv, kv_loc, hd), jnp.bfloat16)

    def fwd(q, k, v):
        return chunked_attention(q, k, v, causal=True,
                                 window=cfg.attn_window)

    cost_fwd = module_cost(jax.jit(fwd).lower(q, k, v).compile().as_text())
    if not train:
        return cost_fwd.flops, cost_fwd.bytes

    def loss(q, k, v):
        return jnp.sum(fwd(q, k, v).astype(jnp.float32))

    grad = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
    cost_bwd = module_cost(grad.lower(q, k, v).compile().as_text())
    # remat recompute: the layer body reruns the forward once in backward
    return (cost_fwd.flops * 2 + cost_bwd.flops,
            cost_fwd.bytes * 2 + cost_bwd.bytes)


def flash_adjusted(cell: dict, cfg: ModelConfig, shape: ShapeConfig,
                   tp: int = 16) -> dict | None:
    """Adjusted roofline for one dry-run cell result dict."""
    if shape.kind == "decode" or _attn_layers(cfg) == 0:
        return None
    roof = cell["roofline"]
    chips = cell["chips"]
    dp = chips // tp
    mb = cell.get("microbatches", 1)
    train = shape.kind == "train"

    b_loc, s_q, h_loc, kv_loc = _local_shapes(cfg, shape, mb, dp, tp)
    hd = cfg.resolved_head_dim
    int_flops, int_bytes = _interior_cost(cfg, b_loc, s_q, shape.seq_len,
                                          h_loc, kv_loc, train)
    layers = _attn_layers(cfg)
    trips = layers * mb
    interior_flops = int_flops * trips
    interior_bytes = int_bytes * trips

    # kernel HBM I/O: fwd reads q,k,v writes o; bwd reads q,k,v,o,do writes
    # dq,dk,dv -> 6 h-sized + 6 kv-sized passes (train); 2h+2kv (fwd only)
    h_pass = b_loc * s_q * h_loc * hd * 2
    kv_pass = b_loc * shape.seq_len * kv_loc * hd * 2
    io = (6 * h_pass + 6 * kv_pass) if train else (2 * h_pass + 2 * kv_pass)
    kernel_bytes = io * trips
    # causal block-skip: keep ~(0.5 + 1/(2*n_blocks)) of interior matmuls
    keep = 0.55
    new_flops = max(roof["flops_per_device"] - interior_flops * (1 - keep),
                    0.0)
    new_bytes = max(roof["bytes_per_device"] - interior_bytes + kernel_bytes,
                    0.0)

    compute_s = new_flops / PEAK_FLOPS_BF16
    memory_s = new_bytes / HBM_BW
    collective_s = roof["collective_s"]
    bound = max(compute_s, memory_s, collective_s)
    per_chip = roof["model_flops_global"] / chips / bound if bound else 0.0
    return {
        "interior_flops_per_device": interior_flops,
        "interior_bytes_per_device": interior_bytes,
        "kernel_io_bytes_per_device": kernel_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": max([("compute", compute_s), ("memory", memory_s),
                         ("collective", collective_s)],
                        key=lambda kv: kv[1])[0],
        "bound_s": bound,
        "roofline_fraction": per_chip / PEAK_FLOPS_BF16,
    }
