"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

    compute    = FLOPs_per_device / peak_flops          (= global/(chips*peak))
    memory     = bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / link_bw

Per-device numbers come from the trip-count-corrected HLO parser
(roofline/hlo_cost.py); the built-in ``cost_analysis()`` values are kept as
debug columns. MODEL_FLOPS is the analytic useful-work count:
6*N*D (train, dense), 6*N_active*D (train, MoE), 2*N*D (inference fwd),
where D = tokens processed by the step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline.hlo_cost import Cost, module_cost

# TPU v5e hardware constants (per the assignment)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (parsed, trip-count-corrected)
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: dict
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # analytic useful work
    model_flops_global: float
    # xla-reported debug values (NOT trip-count corrected)
    xla_flops: Optional[float] = None
    xla_bytes: Optional[float] = None
    memory_per_device_bytes: Optional[int] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (parsed HLO FLOPs x chips): remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput at the bound vs chip peak (the score):
        (MODEL_FLOPS / chips / bound_seconds) / PEAK."""
        if self.bound_s <= 0:
            return 0.0
        per_chip_rate = self.model_flops_global / self.chips / self.bound_s
        return per_chip_rate / PEAK_FLOPS_BF16

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, bound_s=self.bound_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for one step of this (arch x shape) cell."""
    n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


def analyze(hlo_text: str, cfg: ModelConfig, shape: ShapeConfig,
            mesh_name: str, chips: int,
            xla_cost: Optional[dict] = None,
            memory_stats=None) -> Roofline:
    c: Cost = module_cost(hlo_text)
    mem_bytes = None
    if memory_stats is not None:
        mem_bytes = int(memory_stats.argument_size_in_bytes
                        + memory_stats.temp_size_in_bytes
                        + memory_stats.output_size_in_bytes)
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=c.flops,
        bytes_per_device=c.bytes,
        collective_bytes_per_device=c.total_collective_bytes,
        collective_breakdown=dict(c.collective_bytes),
        compute_s=c.flops / PEAK_FLOPS_BF16,
        memory_s=c.bytes / HBM_BW,
        collective_s=c.total_collective_bytes / ICI_LINK_BW,
        model_flops_global=model_flops(cfg, shape),
        xla_flops=(xla_cost or {}).get("flops"),
        xla_bytes=(xla_cost or {}).get("bytes accessed"),
        memory_per_device_bytes=mem_bytes,
    )


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'dominant':>10s} {'useful%':>8s} {'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{100*r['useful_flops_ratio']:7.1f}% "
            f"{100*r['roofline_fraction']:8.2f}%")
    return "\n".join(lines)
