"""Trip-count-aware HLO cost model (parses ``compiled.as_text()``).

Why not ``compiled.cost_analysis()``? XLA's HloCostAnalysis visits a while
body ONCE — it does not multiply by the trip count. Our stacks scan over
layers (and attention/RWKV scan over chunks), so the built-in numbers
under-report FLOPs/bytes by 10-1000x (verified empirically; see
EXPERIMENTS.md §Dry-run methodology). This parser walks the partitioned HLO
module, costing:

  * FLOPs: ``dot`` (2 * result_elems * contracted_elems, from the operand
    shape + contracting dims) and ``convolution`` (2 * out_elems *
    kernel_spatial * cin/groups); descends into fusions/calls,
  * bytes: per top-level op, operands + results (a fusion counts as one op —
    one pass over its inputs/outputs, the roofline-correct model),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), costed at result bytes,

and multiplies ``while`` bodies by ``backend_config.known_trip_count`` (the
scan length jax always emits). All numbers are PER DEVICE for the SPMD
module; multiply by chip count for global.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_tokens(text: str):
    """Yield (dtype, dims) for every TYPE[dims] token in text."""
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        yield dt, shape


def _nelems(shape) -> int:
    return math.prod(shape) if shape else 1


def _tok_bytes(dt, shape) -> float:
    return _nelems(shape) * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_bytes: float
    result_elems: int
    result_shapes: list          # [(dtype, dims), ...]
    operands: list               # %names
    called: list                 # computation names (fusion/call/while...)
    attrs: str                   # raw tail for dot dims / trip count
    line: str

    @property
    def op_name_meta(self) -> str:
        m = re.search(r'op_name="([^"]*)"', self.attrs)
        return m.group(1) if m else ""


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _parse_op(line: str) -> Optional[Op]:
    m = _OP_RE.match(line)
    if not m:
        return None
    name, type_str, kind, rest = m.groups()
    shapes = list(_shape_tokens(type_str))
    rbytes = sum(_tok_bytes(dt, sh) for dt, sh in shapes)
    relems = sum(_nelems(sh) for dt, sh in shapes)
    operands = re.findall(r"%([\w.\-]+)", rest.split("), ")[0]) \
        if ")" in rest else []
    called = []
    for key in ("calls=", "body=", "condition=", "to_apply=",
                "branch_computations={"):
        for mm in re.finditer(re.escape(key) + r"[%{]?%?([\w.\-]+)", rest):
            called.append(mm.group(1))
    return Op(name=name, kind=kind, result_bytes=rbytes, result_elems=relems,
              result_shapes=shapes, operands=operands, called=called,
              attrs=rest, line=line)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict                # %name -> (bytes, shapes)


def parse_module(hlo_text: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line:
            m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)", line.strip())
            if m:
                current = Computation(m.group(2), [], {})
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
                continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        op = _parse_op(line)
        if op is not None:
            current.ops.append(op)
            current.symbols[op.name] = (op.result_bytes, op.result_shapes)
    assert entry is not None, "no ENTRY computation found"
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    # contracted size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * op.result_elems
    dims = [int(d) for d in m.group(1).split(",") if d]
    lhs = comp.symbols.get(op.operands[0])
    if lhs is None or not lhs[1]:
        return 2.0 * op.result_elems
    lhs_shape = lhs[1][0][1]
    contracted = math.prod(lhs_shape[d] for d in dims) if dims else 1
    return 2.0 * op.result_elems * contracted


def _conv_flops(op: Op, comp: Computation) -> float:
    # 2 * out_elems * (kernel spatial elems * cin / groups): approximate via
    # rhs operand elems / cout
    if len(op.operands) < 2:
        return 2.0 * op.result_elems
    rhs = comp.symbols.get(op.operands[1])
    if rhs is None or not rhs[1]:
        return 2.0 * op.result_elems
    rhs_shape = rhs[1][0][1]
    g = 1
    m = re.search(r"feature_group_count=(\d+)", op.attrs)
    if m:
        g = int(m.group(1))
    # HWIO: last dim = cout
    cout = rhs_shape[-1] if rhs_shape else 1
    kernel_per_out = _nelems(rhs_shape) / max(cout, 1)
    return 2.0 * op.result_elems * kernel_per_out / max(g, 1)


def _trip_count(op: Op) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    return int(m.group(1)) if m else 1


_SKIP_BYTES_KINDS = {"parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "optimization-barrier"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_count: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) + v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_count": dict(self.collective_count),
                "total_collective_bytes": self.total_collective_bytes}


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for o in op.operands:
        sym = comp.symbols.get(o)
        if sym is not None:
            total += sym[0]
    return total


# Ops that read only a slice/selection of their (possibly huge, loop-
# invariant) operand: charging full operand bytes per while-iteration would
# wildly overcount HBM traffic (e.g. scan-over-layers dynamic-slicing one
# layer from the stacked params). Charge result-sized reads instead.
_SLICE_READ_KINDS = {"dynamic-slice", "gather", "slice"}


def _op_bytes(op: Op, comp: Computation) -> float:
    if op.kind in _SLICE_READ_KINDS:
        return 2.0 * op.result_bytes               # read slice + write result
    if op.kind == "dynamic-update-slice":
        # in-place update: read+write the updated region only
        upd = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
        upd_bytes = upd[0] if upd else op.result_bytes
        return 2.0 * upd_bytes
    return op.result_bytes + _operand_bytes(op, comp)


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM traffic of a fusion = result + per-parameter reads, where a
    parameter whose only inner uses are dynamic-slice/gather is charged at
    the sliced size (the DMA reads only the slice)."""
    called = [c for c in op.called if c in comps]
    if not called:
        return op.result_bytes + _operand_bytes(op, comp)
    inner = comps[called[0]]
    # map parameter index -> param op name
    param_names = {}
    for iop in inner.ops:
        if iop.kind == "parameter":
            m = re.search(r"parameter\((\d+)\)", iop.attrs)
            if m:
                param_names[iop.name] = int(m.group(1))
    sliced_reads: dict[str, float] = {}
    full_read: set[str] = set()
    for iop in inner.ops:
        for o in iop.operands:
            if o not in param_names:
                continue
            if iop.kind in _SLICE_READ_KINDS and iop.operands and \
                    iop.operands[0] == o:
                sliced_reads[o] = sliced_reads.get(o, 0.0) + iop.result_bytes
            else:
                full_read.add(o)
    total = op.result_bytes
    for pname, pidx in param_names.items():
        if pidx >= len(op.operands):
            continue
        sym = comp.symbols.get(op.operands[pidx])
        full = sym[0] if sym else 0.0
        if pname in full_read or pname not in sliced_reads:
            total += full
        else:
            total += min(full, sliced_reads[pname])
    return total


def cost_computation(name: str, comps: dict, memo: dict,
                     flops_only: bool = False) -> Cost:
    if (name, flops_only) in memo:
        return memo[(name, flops_only)]
    comp = comps[name]
    c = Cost()
    for op in comp.ops:
        kind = op.kind
        if kind == "dot":
            c.flops += _dot_flops(op, comp)
        elif kind == "convolution":
            c.flops += _conv_flops(op, comp)
        if kind == "while":
            trips = _trip_count(op)
            body = [n for n in op.called if "region" in n or "body" in n
                    or n in comps]
            for b in op.called:
                if b in comps:
                    c.add(cost_computation(b, comps, memo, flops_only), trips)
            continue
        if kind in ("fusion", "call", "conditional", "sort", "map",
                    "reduce", "reduce-window", "scatter", "select-and-scatter",
                    "custom-call", "async-start"):
            # descend for flops (dots can live inside fusions); bytes are
            # charged at this op's boundary (one memory pass per fusion)
            for b in op.called:
                if b in comps:
                    sub = cost_computation(b, comps, memo, flops_only=True)
                    c.flops += sub.flops
                    # collectives never live inside fusions; whiles neither
        if not flops_only and kind not in _SKIP_BYTES_KINDS:
            if kind == "fusion":
                c.bytes += _fusion_bytes(op, comp, comps)
            else:
                c.bytes += _op_bytes(op, comp)
        if kind in _COLLECTIVES or any(kind.startswith(x + "-start")
                                       for x in _COLLECTIVES):
            base = kind.replace("-start", "")
            c.collective_bytes[base] = (c.collective_bytes.get(base, 0.0)
                                        + op.result_bytes)
            c.collective_count[base] = c.collective_count.get(base, 0) + 1
    memo[(name, flops_only)] = c
    return c


def module_cost(hlo_text: str) -> Cost:
    """Per-device trip-count-corrected cost of a compiled SPMD module."""
    comps, entry = parse_module(hlo_text)
    return cost_computation(entry, comps, memo={})


# ---------------------------------------------------------------------------
# Region attribution: split costs by HLO metadata op_name patterns
# ---------------------------------------------------------------------------

def region_cost(name: str, comps: dict, patterns: dict, memo: dict) -> dict:
    """Like cost_computation but bucketing (flops, bytes, collective_bytes)
    per region; an op belongs to the first pattern matching its op_name
    metadata, else '_other'. While bodies multiply by trip count."""
    key = (name,)
    if key in memo:
        return memo[key]
    comp = comps[name]
    buckets: dict[str, Cost] = {}

    def bucket_for(op: Op) -> str:
        meta = op.op_name_meta
        for tag, pat in patterns.items():
            if re.search(pat, meta):
                return tag
        return "_other"

    def add(tag, **kw):
        c = buckets.setdefault(tag, Cost())
        c.flops += kw.get("flops", 0.0)
        c.bytes += kw.get("bytes", 0.0)
        for k, v in kw.get("coll", {}).items():
            c.collective_bytes[k] = c.collective_bytes.get(k, 0) + v

    for op in comp.ops:
        tag = bucket_for(op)
        if op.kind == "dot":
            add(tag, flops=_dot_flops(op, comp))
        elif op.kind == "convolution":
            add(tag, flops=_conv_flops(op, comp))
        if op.kind == "while":
            trips = _trip_count(op)
            for b in op.called:
                if b in comps:
                    sub = region_cost(b, comps, patterns, memo)
                    for t, c in sub.items():
                        add(t, flops=c.flops * trips, bytes=c.bytes * trips,
                            coll={k: v * trips
                                  for k, v in c.collective_bytes.items()})
            continue
        if op.kind in ("fusion", "call", "conditional", "sort", "map",
                       "reduce", "reduce-window", "scatter",
                       "select-and-scatter", "custom-call", "async-start"):
            for b in op.called:
                if b in comps:
                    sub = cost_computation(b, comps, {}, flops_only=True)
                    add(tag, flops=sub.flops)
        if op.kind not in _SKIP_BYTES_KINDS:
            if op.kind == "fusion":
                add(tag, bytes=_fusion_bytes(op, comp, comps))
            else:
                add(tag, bytes=_op_bytes(op, comp))
        if op.kind in _COLLECTIVES or any(op.kind.startswith(x + "-start")
                                          for x in _COLLECTIVES):
            base = op.kind.replace("-start", "")
            add(tag, coll={base: op.result_bytes})
    memo[key] = buckets
    return buckets


def module_region_cost(hlo_text: str, patterns: dict) -> dict:
    comps, entry = parse_module(hlo_text)
    return region_cost(entry, comps, patterns, memo={})
