"""Serving-side cache utilities (thin wrappers over model init_cache)."""
from __future__ import annotations

import jax

from repro.models.transformer import Model


def cache_spec(model: Model, batch: int, seq_len: int):
    """ShapeDtypeStruct pytree of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: model.init_cache(batch, seq_len))


def cache_bytes(spec) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(spec))
