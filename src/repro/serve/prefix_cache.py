"""Radix-tree prefix cache over block-paged KV: requests that share a
prompt prefix share physical KV pages.

The PR 5 engine gave every request slot a *page table* — a level of
indirection between logical context positions and physical KV pages. This
module exploits it: a full page's KV content is a pure function of the
``page_size`` token ids it covers (positions are absolute, weights fixed,
kernels deterministic), so two requests whose prompts agree on tokens
``[p*ps, (p+1)*ps)`` can map the *same* physical page at logical index
``p``. A shared system prompt is prefilled once and every later request
skips straight past it — TTFT drops from O(prompt) to O(suffix).

Structure: a radix tree at page granularity. Each edge is labelled by the
``page_size`` token ids a page covers; each node owns one physical page.
Matching a new prompt walks the tree page by page; insertion (at prompt
completion, when the pages are final) adds nodes for the uncached suffix.
The tree holds its own reference on every cached page (see
``PageAllocator`` refcounts), so cached pages survive the request that
wrote them and are reclaimed — LRU leaves first — only under allocator
pressure.

Copy-on-write on the first diverging page: when the match ends mid-page
(the new prompt agrees with a cached page on its first ``r < page_size``
tokens), the cached page cannot be shared directly — the new request must
write its own tokens from offset ``r`` on, and pages are only shared
read-only. Instead ``match`` hands back that page as a COW source: the
scheduler allocates a private page, the engine copies the source onto it
(``paged_kv.copy_page``, one compiled shape), and the request's prefill
overwrites it from the divergence point. The source is pinned (incref) by
``match`` until the copy lands, so eviction can never race it.

Two hard rules keep sharing sound:

* Only *immutable* pages enter the tree: pages fully covered by the
  prompt. Generated tokens are written at positions ``>= len(prompt)``,
  so a partial tail page is still written after prefill and never cached.
* A match is capped at ``len(prompt) - 1`` tokens: the final prompt token
  is always processed by the model, because its logits seed sampling.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serve.paged_kv import PageAllocator


class _Node:
    """One cached page: edge label ``key`` (page_size token ids as bytes),
    physical ``page``, LRU stamp, and parent linkage for leaf eviction."""
    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key: bytes, page: int, parent: "_Node"):
        self.key = key
        self.page = page
        self.children: dict[bytes, _Node] = {}
        self.parent = parent
        self.last_used = 0


class PrefixCache:
    def __init__(self, allocator: PageAllocator, page_size: int,
                 metrics=None):
        self.allocator = allocator
        self.page_size = int(page_size)
        self.root = _Node(b"", 0, None)     # owns no page (trash page id 0)
        self._clock = 0
        # hit/eviction/COW accounting lives in the metrics registry (the
        # stats dict and the Prometheus exposition read the same numbers);
        # standalone caches get a private live registry so counters work
        m = metrics if metrics is not None else MetricsRegistry()
        self._m_queries = m.counter(
            "repro_prefix_queries_total", "prefix-cache match() calls")
        self._m_hit_queries = m.counter(
            "repro_prefix_hit_queries_total",
            "match() calls returning >= 1 cached token")
        self._m_tok_queried = m.counter(
            "repro_prefix_tokens_queried_total", "prompt tokens matched")
        self._m_tok_hit = m.counter(
            "repro_prefix_tokens_hit_total",
            "prompt tokens served from the cache")
        self._m_inserts = m.counter(
            "repro_prefix_inserted_pages_total", "pages newly cached")
        self._m_evictions = m.counter(
            "repro_prefix_evictions_total", "cached pages evicted (LRU)")
        self._m_cow = m.counter(
            "repro_prefix_cow_hits_total",
            "matches ending mid-page (copy-on-write source handed out)")
        self._m_cached_pages = m.gauge(
            "repro_prefix_cached_pages", "pages held by the radix tree")

    # -- bookkeeping --------------------------------------------------------

    # registry-backed spellings of the original counter attributes
    @property
    def n_queries(self) -> int:
        return int(self._m_queries.value())

    @property
    def n_hit_queries(self) -> int:
        return int(self._m_hit_queries.value())

    @property
    def tokens_queried(self) -> int:
        return int(self._m_tok_queried.value())

    @property
    def tokens_hit(self) -> int:
        return int(self._m_tok_hit.value())

    @property
    def n_evicted(self) -> int:
        return int(self._m_evictions.value())

    @property
    def n_cached_pages(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def cached_pages(self) -> list[int]:
        out, stack = [], [self.root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                out.append(ch.page)
                stack.append(ch)
        return out

    @property
    def hit_rate(self) -> float:
        """Fraction of queried prompt tokens served from the cache."""
        return self.tokens_hit / max(self.tokens_queried, 1)

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    def _key(self, tokens: np.ndarray) -> bytes:
        return np.ascontiguousarray(tokens, np.int32).tobytes()

    # -- the cache interface ------------------------------------------------

    def match(self, prompt: np.ndarray
              ) -> tuple[list[int], int, Optional[int]]:
        """Longest cached prefix of ``prompt``, capped at ``len(prompt)-1``.

        Returns ``(pages, n_cached, cow_src)``: ``pages`` are the shared
        full pages covering ``prompt[:len(pages)*page_size]`` — one
        reference per page is taken FOR THE CALLER (the request's page
        table); ``n_cached`` is the total cached token count; when
        ``n_cached`` extends mid-page, ``cow_src`` is the partially
        matching cached page (also incref'd — the caller must copy it onto
        a private page and then release the reference).
        """
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        limit = len(prompt) - 1             # last token always runs
        self._m_queries.inc()
        self._m_tok_queried.inc(len(prompt))

        pages: list[int] = []
        node = self.root
        pos = 0
        while pos + ps <= limit:
            child = node.children.get(self._key(prompt[pos:pos + ps]))
            if child is None:
                break
            self._touch(child)
            self.allocator.incref(child.page)
            pages.append(child.page)
            node = child
            pos += ps

        # first diverging page: the child sharing the longest head with the
        # remaining prompt becomes the COW source
        cow_src, best = None, 0
        rem = prompt[pos:pos + min(ps, limit - pos)]
        if len(rem) > 0:
            for key, child in node.children.items():
                cached = np.frombuffer(key, np.int32)[:len(rem)]
                r = int((np.cumprod(cached == rem)).sum())
                if r > best:
                    best, cow_src = r, child
        if cow_src is not None:
            self._touch(cow_src)
            self.allocator.incref(cow_src.page)
            cow_src = cow_src.page
            self._m_cow.inc()

        n_cached = pos + best
        self._m_tok_hit.inc(n_cached)
        if n_cached > 0:
            self._m_hit_queries.inc()
        return pages, n_cached, cow_src

    def insert(self, prompt: np.ndarray, pages: list) -> int:
        """Cache the immutable prompt pages: ``pages[j]`` must hold the KV
        of ``prompt[j*ps:(j+1)*ps]`` (only pages FULLY covered by the
        prompt may be passed — the partial tail page is still written by
        decode). Existing nodes win (first writer stays, identical content
        by construction); each newly cached page gains a tree reference.
        Returns the number of pages newly cached."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        assert len(pages) * ps <= len(prompt), \
            f"{len(pages)} pages exceed the {len(prompt)}-token prompt"
        node, added = self.root, 0
        for j, page in enumerate(pages):
            key = self._key(prompt[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(page), node)
                node.children[key] = child
                self.allocator.incref(int(page))
                added += 1
            self._touch(child)
            node = child
        if added:
            self._m_inserts.inc(added)
            self._m_cached_pages.add(added)
        return added

    def evict(self, n: int) -> int:
        """Free up to ``n`` cached pages, coldest evictable leaves first.
        A leaf is evictable when the tree is its page's only owner
        (refcount 1) — pages still mapped by a running slot (or pinned as
        an in-flight COW source) are never touched. Evicting a leaf can
        expose its parent; the sweep repeats until satisfied or stuck.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n:
            best: Optional[_Node] = None
            stack = [self.root]
            while stack:
                node = stack.pop()
                for ch in node.children.values():
                    if ch.children:
                        stack.append(ch)
                    elif self.allocator.refcount(ch.page) == 1 and (
                            best is None or ch.last_used < best.last_used):
                        best = ch
            if best is None:
                break
            del best.parent.children[best.key]
            self.allocator.free([best.page])
            freed += 1
        if freed:
            self._m_evictions.inc(freed)
            self._m_cached_pages.add(-freed)
        return freed
