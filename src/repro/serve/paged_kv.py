"""Block-paged KV cache for the continuous-batching serving engine.

The one-ring-per-batch cache (``Model.init_cache``) allocates a dense
``(batch, seq_len, ...)`` buffer per layer: every request pays for the
longest request's context, and a finished request's memory can't be reused
without reallocating (= recompiling) the whole batch. The engine instead
stores KV in fixed-size **pages** — per layer, a pool of
``(n_pages, page_size, kv_heads, head_dim)`` K and V pages shared by every
request slot — and maps each request's logical context onto physical pages
through a per-slot **page table** ``(capacity, max_pages)``: logical page
``p`` of a slot covers absolute positions ``[p*page_size, (p+1)*page_size)``.

Allocation is host-side (a free list — pages are ints, allocation never
enters the jitted step); the jitted step only consumes the page table, so
admitting, finishing, and recycling requests changes *data*, never shapes:
no recompiles as traffic churns. Page 0 is reserved as the trash page —
masked-out token writes land there, and unallocated page-table entries
point at it (their reads are masked by the causal-by-absolute-position
mask in ``models.attention.paged_attention``).

The pool tree mirrors ``Model.init_cache``'s structure (scanned layers
stacked over ``n_super``, unrolled remainder under ``rem``) so it rides
through the same layer-stack ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention
from repro.models.transformer import Model


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages covering a context of ``n_tokens`` positions."""
    return -(-int(n_tokens) // int(page_size))


def init_paged_cache(model: Model, n_pages: int, page_size: int,
                     dtype=None):
    """Paged KV pool pytree for an attention-only model.

    Mirrors ``Model.init_cache``'s tree (``{"layers": stacked, "rem": ...}``)
    with each attention layer's ring buffer replaced by a
    ``(n_pages, page_size, kv, hd)`` page pool. One page table indexes every
    layer's pool identically (all layers cache the same positions), so the
    engine allocates pages once per request, not per layer.
    """
    cfg = model.cfg
    if model.paged_step is None:
        raise NotImplementedError(
            f"{cfg.name}: the paged engine covers attention-only "
            "architectures with a non-int8 KV cache "
            f"(block_pattern={cfg.block_pattern}, "
            f"kv_cache_dtype={cfg.kv_cache_dtype!r})")
    dtype = dtype or jnp.dtype(cfg.compute_dtype)

    def one_super():
        return {f"b{i}_{kind}": {"attn": attention.init_paged_kv(
                    cfg, n_pages, page_size, dtype)}
                for i, kind in enumerate(cfg.block_pattern)}

    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_super_blocks,) + x.shape).copy(),
        one_super())
    pools = {"layers": stacked}
    rem = cfg.remainder_pattern
    if rem:
        pools["rem"] = {f"r{i}_{kind}": {"attn": attention.init_paged_kv(
                            cfg, n_pages, page_size, dtype)}
                        for i, kind in enumerate(rem)}
    return pools


def paged_cache_bytes(pools) -> int:
    """Total bytes of the page pools (all layers)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(pools))


class PageAllocator:
    """Host-side free-list page allocator. Page 0 is reserved (trash page).

    ``alloc(n)`` pops ``n`` page ids (lowest-numbered first — keeps page
    tables dense and reproducible) or raises ``MemoryError`` without
    allocating anything; ``free(pages)`` returns them. The engine reserves
    a request's worst-case page count at admission, so a running request
    can never hit an out-of-pages condition mid-flight (no preemption
    needed).
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is reserved), "
                             f"got {n_pages}")
        self.n_pages = int(n_pages)
        # descending so .pop() hands out the lowest id first
        self._free = list(range(self.n_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"requested {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            p = int(p)
            assert 0 < p < self.n_pages, p
            self._free.append(p)
