"""Slot resource pools for the continuous-batching serving engine.

The one-ring-per-batch cache (``Model.init_cache``) allocates a dense
``(batch, seq_len, ...)`` buffer per layer: every request pays for the
longest request's context, and a finished request's memory can't be reused
without reallocating (= recompiling) the whole batch. The engine instead
gives every request slot a **slot resource pool** per layer, of which there
are two kinds, keyed by the layer kind:

* **Block-paged KV** (``attn`` layers): a pool of
  ``(n_pages, page_size, kv_heads, head_dim)`` K and V pages shared by
  every request slot, mapped onto each request's logical context through a
  per-slot **page table** ``(capacity, max_pages)``: logical page ``p`` of
  a slot covers absolute positions ``[p*page_size, (p+1)*page_size)``.
  Int8-KV configs store int8 pages plus per-(page, offset, head) f32
  scales (``attention.init_paged_kv``).
* **Slot-indexed recurrent state** (``rglru``/``rwkv`` layers): fixed-size
  state arrays with a leading ``capacity`` axis — slot ``i``'s state lives
  at index ``i``. No paging: recurrent state is O(1) per slot regardless
  of context length, so these slots need no admission-time reservation.

Both kinds coexist in one pool tree for hybrid block patterns (e.g.
recurrentgemma's 2:1 RG-LRU:attention pattern), mirroring
``Model.init_cache``'s structure (scanned layers stacked over ``n_super``,
unrolled remainder under ``rem``) so the tree rides through the same
layer-stack ``lax.scan``.

Page allocation is host-side (a free list — pages are ints, allocation
never enters the jitted step); the jitted step only consumes the page
table, so admitting, finishing, and recycling requests changes *data*,
never shapes: no recompiles as traffic churns. Page 0 is reserved as the
trash page — masked-out token writes land there, and unallocated
page-table entries point at it (their reads are masked by the
causal-by-absolute-position mask in ``models.attention.paged_attention``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, rglru, rwkv6
from repro.models.transformer import Model
from repro.obs.metrics import NULL_REGISTRY

# pool-subtree keys holding slot-indexed recurrent state (vs "attn" pages)
_STATE_KEYS = ("rec", "tm", "cm")


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages covering a context of ``n_tokens`` positions."""
    return -(-int(n_tokens) // int(page_size))


def unsupported_kinds(model: Model) -> list[str]:
    """Layer kinds in the model outside the engine's pool coverage."""
    cfg = model.cfg
    kinds = tuple(cfg.block_pattern) + tuple(cfg.remainder_pattern)
    return sorted({k for k in kinds if k not in ("attn", "rglru", "rwkv")})


def _layer_pools(cfg, kind: str, n_pages: int, page_size: int, dtype,
                 capacity: int) -> dict:
    if kind == "attn":
        return {"attn": attention.init_paged_kv(cfg, n_pages, page_size,
                                                dtype)}
    if kind == "rglru":
        return {"rec": rglru.init_rglru_state(cfg, capacity, dtype)}
    if kind == "rwkv":
        st = rwkv6.init_rwkv_state(cfg, capacity)
        return {"tm": st["tm"], "cm": st["cm"]}
    raise NotImplementedError(
        f"layer kind {kind!r} has no slot resource pool — the engine "
        "covers attn/rglru/rwkv; use the sequential serving path "
        "(launch/serve without --engine)")


def init_paged_cache(model: Model, n_pages: int, page_size: int,
                     dtype=None, *, capacity: int = 1):
    """Slot resource pool pytree for any engine-served model.

    Mirrors ``Model.init_cache``'s tree (``{"layers": stacked, "rem": ...}``)
    with each attention layer's ring buffer replaced by a
    ``(n_pages, page_size, kv, hd)`` page pool and each recurrent layer's
    state replaced by a ``capacity``-slot state pool. One page table
    indexes every attention layer's pool identically (all layers cache the
    same positions), so the engine allocates pages once per request, not
    per layer. ``capacity`` is the engine's slot-batch size (the leading
    axis of every state-pool leaf).
    """
    cfg = model.cfg
    if model.paged_step is None:
        bad = unsupported_kinds(model)
        raise NotImplementedError(
            f"{cfg.name}: layer kind(s) {', '.join(map(repr, bad))} have no "
            "slot resource pool — the engine covers attn/rglru/rwkv; use "
            "the sequential serving path (launch/serve without --engine)")
    dtype = dtype or jnp.dtype(cfg.compute_dtype)

    def one_super():
        return {f"b{i}_{kind}": _layer_pools(cfg, kind, n_pages, page_size,
                                             dtype, capacity)
                for i, kind in enumerate(cfg.block_pattern)}

    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_super_blocks,) + x.shape).copy(),
        one_super())
    pools = {"layers": stacked}
    rem = cfg.remainder_pattern
    if rem:
        pools["rem"] = {f"r{i}_{kind}": _layer_pools(
                            cfg, kind, n_pages, page_size, dtype, capacity)
                        for i, kind in enumerate(rem)}
    return pools


def paged_cache_bytes(pools) -> int:
    """Total bytes of the slot resource pools (all layers, both kinds)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(pools))


def slot_resource_bytes(pools) -> dict:
    """Byte split of the pool tree by resource kind.

    Returns ``{"kv_page_bytes": ..., "state_slot_bytes": ...}`` — paged KV
    pools (the ``"attn"`` subtrees, scales included) vs slot-indexed
    recurrent state pools (the ``"rec"``/``"tm"``/``"cm"`` subtrees). The
    two sum to ``paged_cache_bytes(pools)``.
    """
    split = {"kv_page_bytes": 0, "state_slot_bytes": 0}
    for group in ("layers", "rem"):
        for layer in (pools.get(group) or {}).values():
            for key, sub in layer.items():
                kind = "kv_page_bytes" if key == "attn" else "state_slot_bytes"
                split[kind] += sum(int(x.size) * x.dtype.itemsize
                                   for x in jax.tree.leaves(sub))
    return split


def zero_state_slots(pools, mask):
    """Zero the recurrent state of the slots selected by ``mask``.

    mask: (capacity,) bool. Touches only the state-pool subtrees
    (``rec``/``tm``/``cm``) — paged-KV pages are recycled through the page
    allocator instead. Slot hygiene on recycle: a finished request's state
    must not be readable by the slot's next occupant. (The in-step reset in
    ``transformer._apply_layer_paged`` re-zeroes on first prefill chunk
    regardless — this keeps the pool clean between occupants.)

    In the stacked ``"layers"`` group the slot axis is axis 1 (leaves are
    ``(n_super, capacity, ...)``); in ``"rem"`` it is axis 0.
    """
    mask = jnp.asarray(mask)

    def zero_group(group, lead):
        def zero_leaf(l):
            shape = (1,) * lead + (-1,) + (1,) * (l.ndim - lead - 1)
            return jnp.where(mask.reshape(shape), jnp.zeros_like(l), l)

        return {key: (jax.tree.map(zero_leaf, sub)
                      if key in _STATE_KEYS else sub)
                for key, sub in group.items()}

    out = {"layers": {name: zero_group(layer, 1)
                      for name, layer in pools["layers"].items()}}
    if "rem" in pools:
        out["rem"] = {name: zero_group(layer, 0)
                      for name, layer in pools["rem"].items()}
    return out


def copy_page(pools, src, dst):
    """Copy page ``src`` onto page ``dst`` in every attention pool leaf
    (K, V, and int8 scales) — the copy-on-write step behind prefix-cache
    hits that end mid-page: the new request maps the cached full pages
    read-only and gets a private copy of the partially-matching boundary
    page, which its own prefill then overwrites from the divergence point.
    ``src``/``dst`` are scalars, so this compiles exactly once regardless
    of which pages are copied.

    In the stacked ``"layers"`` group the page axis is axis 1 (leaves are
    ``(n_super, n_pages, page_size, ...)``); in ``"rem"`` it is axis 0.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp_group(group, lead):
        def cp_leaf(l):
            if lead == 0:
                return l.at[dst].set(l[src])
            return l.at[:, dst].set(l[:, src])

        return {key: (jax.tree.map(cp_leaf, sub) if key == "attn" else sub)
                for key, sub in group.items()}

    out = {"layers": {name: cp_group(layer, 1)
                      for name, layer in pools["layers"].items()}}
    if "rem" in pools:
        out["rem"] = {name: cp_group(layer, 0)
                      for name, layer in pools["rem"].items()}
    return out


class PageAllocator:
    """Host-side refcounted free-list page allocator. Page 0 is reserved
    (trash page) and can never be allocated, shared, or freed.

    ``alloc(n)`` pops ``n`` page ids (lowest-numbered first — keeps page
    tables dense and reproducible) at refcount 1, or raises ``MemoryError``
    without allocating anything. Pages are shared by ``incref`` (the prefix
    cache maps one physical page into many requests' page tables — and
    holds its own reference so cached pages survive their writer) and
    released by ``free``/``decref``: a page returns to the free list only
    when its last owner lets go. ``refcount`` is the test suite's invariant
    hook: at every tick it must equal the number of distinct owners (slot
    page tables + radix-tree nodes + in-flight COW sources).
    """

    def __init__(self, n_pages: int, metrics=NULL_REGISTRY):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is reserved), "
                             f"got {n_pages}")
        self.n_pages = int(n_pages)
        # descending so .pop() hands out the lowest id first
        self._free = list(range(self.n_pages - 1, 0, -1))
        self._rc: dict[int, int] = {}      # page -> refcount (allocated only)
        # occupancy + free-list churn instruments (obs/metrics.py)
        self._m_in_use = metrics.gauge(
            "repro_pages_in_use", "KV pages currently allocated")
        self._m_free = metrics.gauge(
            "repro_pages_free", "KV pages on the free list")
        self._m_allocs = metrics.counter(
            "repro_page_allocs_total", "pages handed out by alloc()")
        self._m_frees = metrics.counter(
            "repro_page_frees_total", "pages returned to the free list")
        self._m_free.set(len(self._free))
        self._m_in_use.set(0)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def _sync_gauges(self) -> None:
        self._m_free.set(len(self._free))
        self._m_in_use.set(self.n_pages - 1 - len(self._free))

    def refcount(self, page: int) -> int:
        return self._rc.get(int(page), 0)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"requested {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        if pages:
            self._m_allocs.inc(len(pages))
            self._sync_gauges()
        return pages

    def incref(self, page: int) -> None:
        p = int(page)
        assert p in self._rc, f"incref on unallocated page {p}"
        self._rc[p] += 1

    def free(self, pages) -> None:
        """Drop one reference per page; last owner returns it to the free
        list. Freeing an unallocated (or trash) page is a hard error — the
        double-free invariant the stress suite leans on."""
        returned = 0
        for p in pages:
            p = int(p)
            assert 0 < p < self.n_pages, p
            rc = self._rc.get(p)
            assert rc is not None, f"double free of page {p}"
            if rc == 1:
                del self._rc[p]
                self._free.append(p)
                returned += 1
            else:
                self._rc[p] = rc - 1
        if returned:
            self._m_frees.inc(returned)
            self._sync_gauges()

    decref = free
