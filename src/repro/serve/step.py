"""serve_step factories: prefill and decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``serve_step`` — one new
token against a seq_len-deep cache — per the assignment. Greedy sampling is
the default; the sampler is pluggable (temperature / top-k live here, not in
the model).

All factories are compression-transparent: ``params`` may be a raw param
tree or a ``repro.sparse.compress.CompressedParams``, in which case every
projection with a BlockCSR entry runs on the compressed kernel path
(the paper's serve-from-compressed-form promise).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def sample_token(logits, temperature: float = 0.0, rng=None):
    """logits (B, vocab) -> token ids (B,) int32 (greedy or sampled)."""
    if temperature > 0.0 and rng is not None:
        tok = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    return tok.astype(jnp.int32)


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        """Full-sequence forward; logits only for the last position (the
        full (B, S, vocab) logits tensor is never materialized — it would be
        petabyte-scale at 32k x 256k vocab)."""
        hidden, aux = model.apply_hidden(params, batch)
        return model.head(params, hidden[:, -1:])[:, 0], aux
    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0) -> Callable:
    def decode_step(params, inputs, cache, pos, rng=None):
        """inputs: (B, 1) ids (or (B, 1, d) frontend embeddings)."""
        logits, cache = model.decode_step(params, inputs, cache, pos)
        logits = logits[:, 0]
        tok = sample_token(logits, temperature, rng)
        return tok, logits, cache
    return decode_step


def generate(model: Model, params, prompt, steps: int,
             temperature: float = 0.0, rng=None):
    """Batched greedy/sampled generation: one prefill dispatch for the whole
    prompt (``model.prefill`` fills the KV cache in a single forward),
    then the decode loop — instead of O(prompt_len) stepwise jit dispatches."""
    b, s = prompt.shape
    cache = model.init_cache(b, s + steps)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model, temperature))

    def next_key():
        nonlocal rng
        if rng is None:
            return None
        rng, sub = jax.random.split(rng)
        return sub

    logits, cache = prefill(params, prompt, cache)
    tok = sample_token(logits, temperature, next_key())
    out = [tok]
    for t in range(s, s + steps - 1):
        tok, logits, cache = decode(params, out[-1][:, None], cache,
                                    jnp.int32(t), next_key())
        out.append(tok)
    return jnp.stack(out, axis=1)
