"""serve_step factories: prefill and decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``serve_step`` — one new
token against a seq_len-deep cache — per the assignment. Greedy sampling is
the default; the sampler is pluggable and configured by a single typed
value: ``repro.serve.api.SamplingParams`` (temperature / top-k / top-p live
there, not in the model). Loose ``temperature=``/``top_k=``/``top_p=``
kwargs still work through a deprecation shim that warns once.

All factories are compression-transparent: ``params`` may be a raw param
tree or a ``repro.sparse.compress.CompressedParams``, in which case every
projection with a BlockCSR entry runs on the compressed kernel path
(the paper's serve-from-compressed-form promise).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.serve.api import SamplingParams, merge_legacy_sampling


def _top_k_mask(logits, top_k: int):
    """Keep the top-k logits, set the rest to -inf. Ties at the k-th value
    all survive (standard top-k semantics)."""
    kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
    return jnp.where(logits >= kth, logits, -jnp.inf)


def _top_p_mask(logits, top_p: float):
    """Nucleus filter: keep the smallest set of tokens whose cumulative
    probability reaches ``top_p`` (always at least the argmax — the
    exclusive cumsum of the most-probable token is 0 < top_p)."""
    sort_idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, sort_idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs    # exclusive cumsum
    drop_sorted = cum_before >= top_p
    # un-sort the drop mask back to vocabulary order (inverse permutation)
    inv = jnp.argsort(sort_idx, axis=-1)
    drop = jnp.take_along_axis(drop_sorted, inv, axis=-1)
    return jnp.where(drop, -jnp.inf, logits)


def sample_token(logits, temperature: float = 0.0, rng=None,
                 top_k: int = 0, top_p: float = 1.0):
    """logits (B, vocab) -> token ids (B,) int32.

    temperature == 0 (or no rng): greedy argmax. Otherwise sample from
    ``softmax(logits / temperature)`` after optional top-k truncation
    (``top_k > 0``) and nucleus / top-p filtering (``top_p < 1``); both
    filters applied means top-k first, then top-p over the survivors —
    filters run on the temperature-scaled logits. jit-safe for static
    ``top_k`` / ``top_p``. This is the scalar-level kernel; close over a
    ``SamplingParams`` via ``make_sampler`` instead of threading scalars.
    """
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k and top_k > 0:
        scaled = _top_k_mask(scaled, int(top_k))
    if top_p < 1.0:
        scaled = _top_p_mask(scaled, float(top_p))
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)


def _as_sampling(sampling, where: str, temperature, top_k,
                 top_p) -> SamplingParams:
    """Accept the typed value, or legacy loose scalars (warning once).
    A bare float in the ``sampling`` slot is the historical positional
    ``temperature`` — folded through the same shim."""
    if sampling is not None and not isinstance(sampling, SamplingParams):
        temperature = sampling          # legacy positional temperature
        sampling = None
    return merge_legacy_sampling(sampling, where, temperature, top_k, top_p)


def make_sampler(sampling: Optional[SamplingParams] = None,
                 top_k: Optional[int] = None, top_p: Optional[float] = None,
                 *, temperature: Optional[float] = None) -> Callable:
    """Pluggable sampler factory for the serving engine: returns
    ``sampler(logits, rng) -> (B,) int32`` with a ``SamplingParams`` closed
    over (so the returned callable is shape-only and jit-stable). Legacy
    ``make_sampler(temperature, top_k, top_p)`` still works (warns once)."""
    sp = _as_sampling(sampling, "serve.step.make_sampler", temperature,
                      top_k, top_p)

    def sampler(logits, rng=None):
        return sample_token(logits, sp.temperature, rng, top_k=sp.top_k,
                            top_p=sp.top_p)
    return sampler


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        """Full-sequence forward; logits only for the last position (the
        full (B, S, vocab) logits tensor is never materialized — it would be
        petabyte-scale at 32k x 256k vocab)."""
        hidden, aux = model.apply_hidden(params, batch)
        return model.head(params, hidden[:, -1:])[:, 0], aux
    return prefill_step


def make_decode_step(model: Model,
                     sampling: Optional[SamplingParams] = None,
                     top_k: Optional[int] = None,
                     top_p: Optional[float] = None, *,
                     temperature: Optional[float] = None) -> Callable:
    sp = _as_sampling(sampling, "serve.step.make_decode_step", temperature,
                      top_k, top_p)

    def decode_step(params, inputs, cache, pos, rng=None):
        """inputs: (B, 1) ids (or (B, 1, d) frontend embeddings)."""
        logits, cache = model.decode_step(params, inputs, cache, pos)
        logits = logits[:, 0]
        tok = sample_token(logits, sp.temperature, rng, top_k=sp.top_k,
                           top_p=sp.top_p)
        return tok, logits, cache
    return decode_step


def generate(model: Model, params, prompt, steps: int,
             sampling: Optional[SamplingParams] = None, rng=None,
             top_k: Optional[int] = None, top_p: Optional[float] = None, *,
             temperature: Optional[float] = None):
    """Batched greedy/sampled generation: one prefill dispatch for the whole
    prompt (``model.prefill`` fills the KV cache in a single forward),
    then the decode loop — instead of O(prompt_len) stepwise jit dispatches.

    ``sampling`` is the typed contract (``SamplingParams``; default
    greedy); the historical ``generate(..., temperature=, top_k=, top_p=)``
    spelling keeps working through a once-warning shim. ``rng`` stays a
    separate argument: it is execution state (a jax PRNG key), not part of
    the serializable request contract.
    """
    sp = _as_sampling(sampling, "serve.step.generate", temperature, top_k,
                      top_p)
    b, s = prompt.shape
    cache = model.init_cache(b, s + steps)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model, sp))

    def next_key():
        nonlocal rng
        if rng is None:
            return None
        rng, sub = jax.random.split(rng)
        return sub

    logits, cache = prefill(params, prompt, cache)
    tok = sample_token(logits, sp.temperature, next_key(), top_k=sp.top_k,
                       top_p=sp.top_p)
    out = [tok]
    for t in range(s, s + steps - 1):
        tok, logits, cache = decode(params, out[-1][:, None], cache,
                                    jnp.int32(t), next_key())
        out.append(tok)
    return jnp.stack(out, axis=1)
