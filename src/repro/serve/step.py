"""serve_step factories: prefill and decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``serve_step`` — one new
token against a seq_len-deep cache — per the assignment. Greedy sampling is
the default; the sampler is pluggable (temperature / top-k live here, not in
the model).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        """Full-sequence forward; logits only for the last position (the
        full (B, S, vocab) logits tensor is never materialized — it would be
        petabyte-scale at 32k x 256k vocab)."""
        hidden, aux = model.apply_hidden(params, batch)
        return model.head(params, hidden[:, -1:])[:, 0], aux
    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0) -> Callable:
    def decode_step(params, inputs, cache, pos, rng=None):
        """inputs: (B, 1) ids (or (B, 1, d) frontend embeddings)."""
        logits, cache = model.decode_step(params, inputs, cache, pos)
        logits = logits[:, 0]
        if temperature > 0.0 and rng is not None:
            tok = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        return tok.astype(jnp.int32), logits, cache
    return decode_step


def generate(model: Model, params, prompt, steps: int,
             temperature: float = 0.0, rng=None):
    """Simple batched greedy/sampled generation loop (examples/serving)."""
    b, s = prompt.shape
    cache = model.init_cache(b, s + steps)
    decode = jax.jit(make_decode_step(model, temperature))
    # prefill by stepping the prompt (simple; prefill kernel is in step.py)
    tok = None
    for t in range(s):
        tok, logits, cache = decode(params, prompt[:, t:t + 1], cache,
                                    jnp.int32(t), rng)
    out = [tok]
    for t in range(s, s + steps - 1):
        tok, logits, cache = decode(params, out[-1][:, None], cache,
                                    jnp.int32(t), rng)
        out.append(tok)
    return jnp.stack(out, axis=1)
