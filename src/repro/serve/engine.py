"""Continuous-batching serving engine over compressed (or dense) weights.

``ServeEngine`` serves many concurrent, mixed-length requests from a single
fixed-capacity slot batch: each tick runs **one jitted mixed step**
(``Model.paged_step``) over all slots — any mix of prefill chunks and
single-token decodes, inactive slots masked by ``n_tokens == 0`` — then a
pluggable sampler, then host-side bookkeeping (admission, streaming
callbacks, slot recycling). ``params`` may be a raw tree or
``CompressedParams`` (BlockCSR / PaletteBCSR, sharded or not): the mixed
step dispatches the same ``sparse_matmul`` kernels as the sequential
serving path, so the engine is compression- and sharding-transparent.

Because the scheduler emits at most three tick widths (1,
``prefill_chunk`` and the optional ``first_chunk`` jumbo width), the step
compiles at most three times and then never again — request churn only
changes array *contents*. Per-request memory lives in the slot resource
pools of ``serve/paged_kv.py`` — block-paged KV for attention layers
(int8 pages + scales for int8-KV configs), slot-indexed recurrent state
for RWKV / RG-LRU layers, coexisting in one tree for hybrids — and pools
are donated back to the step each tick, so they update in place where the
backend supports donation. Recurrent slots are admission-free (pages are
reserved only when the model has attention layers), and a recycled slot's
recurrent state is zeroed before its next occupant. Attention inside the
step dispatches by ``EngineConfig.attn_backend``: the 'pallas' backend
walks page tables with the fused flash-decode kernel
(``kernels/paged_attention``) instead of gathering the whole pool.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.obs.metrics import MetricsRegistry, slo_summary
from repro.obs.trace import NULL_TRACER
from repro.serve import api
from repro.serve.api import ApiValidationError, Completion, SamplingParams
from repro.serve.paged_kv import (PageAllocator, copy_page, init_paged_cache,
                                  pages_for, slot_resource_bytes,
                                  unsupported_kinds, zero_state_slots)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Scheduler
from repro.serve.scheduler import Request as _SchedRequest
from repro.serve.step import make_sampler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of the continuous-batching engine.

    max_batch:     fixed slot capacity of the jitted mixed step.
    prefill_chunk: prompt tokens consumed per slot per tick (long prompts
                   prefill across many ticks, interleaved with decode).
    page_size:     KV page length in tokens.
    max_seq_len:   per-request context cap (prompt + generated) — sets the
                   page-table width.
    n_pages:       total pages per layer pool; default sizes every slot for
                   ``max_seq_len`` (+1 for the reserved trash page 0).
    token_budget:  max tokens scheduled per tick (decode first, remainder
                   to prefill chunks); default ``max_batch + first_chunk``
                   (or ``+ prefill_chunk`` when no jumbo width is set).
    first_chunk:   optional jumbo width (> prefill_chunk) for the FIRST
                   chunk of a long prompt — a third compiled tick width
                   that keeps TTFT off the steady-state chunk pace.
    attn_backend:  paged-attention kernel dispatch: 'pallas' = fused
                   page-gather flash-decode kernel, 'ref' = jnp gather
                   oracle, 'auto' (default) = pallas on TPU, ref elsewhere.
    kv_splits:     flash-decode KV-split lanes per slot on the pallas
                   backend (1 = no split).
    prefix_cache:  radix-tree prefix caching: requests sharing a prompt
                   prefix share physical KV pages (refcounted, COW on the
                   first diverging page) — attention-layer models only
                   (recurrent state is not position-sliceable).
    class_shares:  optional ((class, weight), ...) pairs overriding the
                   per-priority-class prefill token-budget shares
                   (default: class c weighs 2^-c).
    sampling:      engine-wide ``SamplingParams`` — the sampler is part of
                   the compiled step, so it is a property of the engine,
                   not the request (a request carrying explicit sampling
                   must match it). Legacy loose ``temperature``/``top_k``/
                   ``top_p`` fields fold into it with a one-time warning.

    One ``EngineConfig`` value is everything needed to spawn an identical
    replica — the router serializes it (``to_json``/``from_json``) as its
    wire format and builds every replica from the same instance.
    """
    max_batch: int = 8
    prefill_chunk: int = 32
    page_size: int = 16
    max_seq_len: int = 256
    n_pages: Optional[int] = None
    token_budget: Optional[int] = None
    first_chunk: Optional[int] = None
    attn_backend: str = "auto"
    kv_splits: int = 1
    prefix_cache: bool = False
    class_shares: Optional[tuple] = None
    sampling: SamplingParams = SamplingParams()
    # deprecated loose spellings — fold into ``sampling`` (warn once)
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    top_p: Optional[float] = None

    def __post_init__(self):
        legacy = {k: getattr(self, k)
                  for k in ("temperature", "top_k", "top_p")
                  if getattr(self, k) is not None}
        if legacy:
            merged = api.merge_legacy_sampling(
                None if self.sampling == SamplingParams() else self.sampling,
                "serve.engine.EngineConfig", **legacy)
            object.__setattr__(self, "sampling", merged)
            for k in legacy:
                object.__setattr__(self, k, None)
        if self.class_shares is not None:
            object.__setattr__(self, "class_shares",
                               tuple((int(c), float(w))
                                     for c, w in self.class_shares))

    @property
    def pages_per_slot(self) -> int:
        return pages_for(self.max_seq_len, self.page_size)

    @property
    def total_pages(self) -> int:
        return (self.n_pages if self.n_pages is not None
                else self.max_batch * self.pages_per_slot + 1)

    def to_json(self) -> dict:
        """Plain-dict form (the router wire format / replica spawn spec)."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)
             if f.name not in ("temperature", "top_k", "top_p")
             and getattr(self, f.name) != f.default}
        if "sampling" in d:
            d["sampling"] = self.sampling.to_json()
        if self.class_shares is not None:
            d["class_shares"] = [list(p) for p in self.class_shares]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "EngineConfig":
        allowed = tuple(f.name for f in dataclasses.fields(cls)
                        if f.name not in ("temperature", "top_k", "top_p"))
        api._check_keys(d, allowed, "engine_config")
        kw = dict(d)
        if kw.get("sampling") is not None:
            kw["sampling"] = SamplingParams.from_json(
                kw["sampling"], "engine_config.sampling")
        if kw.get("class_shares") is not None:
            kw["class_shares"] = tuple(tuple(p) for p in kw["class_shares"])
        return cls(**kw)


class ServeEngine:
    """The step loop. ``sampler(logits, rng) -> tokens`` runs inside the
    jitted step; default is built from ``config.sampling`` via
    ``serve.step.make_sampler`` (greedy when temperature == 0)."""

    def __init__(self, model: Model, params, config: EngineConfig,
                 sampler: Optional[Callable] = None, rng=None, *,
                 metrics=None, tracer=None, profiler=None):
        if model.paged_step is None:
            bad = unsupported_kinds(model)
            raise NotImplementedError(
                f"{model.cfg.name}: layer kind(s) {', '.join(map(repr, bad))}"
                " have no slot resource pool — the engine covers "
                "attn/rglru/rwkv; use the sequential serving path "
                "(launch/serve without --engine)")
        self.model = model
        self.params = params
        self.config = config
        kinds = (tuple(model.cfg.block_pattern)
                 + tuple(model.cfg.remainder_pattern))
        self.has_attn = "attn" in kinds
        self.has_state = any(k in ("rglru", "rwkv") for k in kinds)
        # telemetry: one registry shared by the allocator, prefix cache,
        # scheduler, and the engine's own tick instruments — the stats dict
        # and the Prometheus exposition read the same numbers. Default is a
        # live (cheap) registry; pass obs.NULL_REGISTRY to strip telemetry
        # entirely (stats counters then read 0), obs.Tracer for a lifecycle
        # trace, obs.Profiler to time the jitted step.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.profiler = profiler
        self.pools = init_paged_cache(model, config.total_pages,
                                      config.page_size,
                                      capacity=config.max_batch)
        self.pool_bytes = slot_resource_bytes(self.pools)
        self.allocator = PageAllocator(config.total_pages,
                                       metrics=self.metrics)
        self.prefix_cache = None
        if config.prefix_cache:
            if self.has_state:
                raise NotImplementedError(
                    f"{model.cfg.name}: --prefix-cache shares paged KV, but "
                    "recurrent (rglru/rwkv) state is not position-sliceable "
                    "— prefix caching covers attention-only models")
            self.prefix_cache = PrefixCache(self.allocator, config.page_size,
                                            metrics=self.metrics)
        self.scheduler = Scheduler(
            capacity=config.max_batch, prefill_chunk=config.prefill_chunk,
            allocator=self.allocator, page_size=config.page_size,
            max_pages=config.pages_per_slot,
            token_budget=config.token_budget,
            first_chunk=config.first_chunk,
            paged=self.has_attn,
            prefix_cache=self.prefix_cache,
            class_shares=dict(config.class_shares or ()),
            metrics=self.metrics, tracer=self.tracer)
        self._m_ticks = self.metrics.counter(
            "repro_engine_ticks_total", "engine ticks, by compiled width",
            labelnames=("width",))
        self._m_tick_tokens = self.metrics.histogram(
            "repro_engine_tick_tokens",
            "tokens scheduled per tick (token-budget utilization)")
        self._m_sampler_batch = self.metrics.histogram(
            "repro_engine_sampler_batch",
            "slots consuming their sampled token per tick")
        self._m_occupancy = self.metrics.histogram(
            "repro_engine_page_occupancy", "allocated KV pages per tick")
        self._m_requests = self.metrics.counter(
            "repro_engine_requests_total", "requests submitted, by class",
            labelnames=("request_class",))
        self._m_finished = self.metrics.counter(
            "repro_engine_requests_finished_total",
            "requests finished, by class", labelnames=("request_class",))
        self._m_gen_tokens = self.metrics.counter(
            "repro_engine_generated_tokens_total", "tokens generated")
        sampler = sampler or make_sampler(config.sampling)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._next_rid = 0
        self.tick_widths: set[int] = set()   # distinct compiled step shapes

        def _step(params, pools, tokens, page_table, start_pos, n_tokens,
                  rng):
            logits, pools = model.paged_step(params, tokens, pools,
                                             page_table, start_pos, n_tokens,
                                             backend=config.attn_backend,
                                             kv_splits=config.kv_splits)
            return sampler(logits, rng), logits, pools

        # donate the pools: the KV pages update in place instead of
        # copying the whole pool every tick (no-op on backends without
        # donation support)
        self._step = jax.jit(_step, donate_argnums=(1,))
        # slot hygiene: zero a recycled slot's recurrent state before its
        # next occupant (one compiled shape — the mask is (capacity,) bool)
        self._zero_slots = (jax.jit(zero_state_slots, donate_argnums=(0,))
                            if self.has_state else None)
        # COW boundary-page copy for mid-page prefix-cache hits (scalar
        # src/dst: one compiled shape no matter which pages are copied)
        self._copy_page = jax.jit(copy_page, donate_argnums=(0,))

    @property
    def n_ticks(self) -> int:
        """Total ticks run (registry-backed; all compiled widths)."""
        return int(self._m_ticks.total())

    # -- request intake -----------------------------------------------------

    def submit(self, request=None, max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None,
               stream: Optional[Callable] = None, priority=None) -> int:
        """Queue one ``api.Request``; returns its request id.

        The typed call is ``submit(api.Request(...), stream=...)`` —
        ``stream(event: api.StreamEvent)`` fires for every generated token
        as it is produced. The legacy spelling
        ``submit(prompt, max_new_tokens, eos_id, stream, priority)`` keeps
        working through a once-warning shim (its callback keeps the old
        ``stream(rid, token, done)`` signature).

        A request carrying explicit ``sampling`` must match the engine's
        compiled ``config.sampling`` — the sampler is engine-wide.
        """
        if not isinstance(request, api.Request):
            # legacy path: first positional was the raw prompt
            api._warn_once(
                "serve.engine.ServeEngine.submit",
                "ServeEngine.submit(prompt, max_new_tokens, ...) is "
                "deprecated; pass serve.api.Request (stream callbacks "
                "then receive a StreamEvent)")
            if request is None or max_new_tokens is None:
                raise ApiValidationError(
                    "submit() needs an api.Request (or legacy prompt + "
                    "max_new_tokens)")
            legacy_stream = stream
            if stream is not None:
                def stream(ev, _cb=legacy_stream):
                    _cb(ev.request_id, ev.token, ev.done)
            request = api.Request(
                prompt=request, max_new_tokens=max_new_tokens,
                eos_id=eos_id,
                priority=1 if priority is None else priority)
        elif max_new_tokens is not None or eos_id is not None \
                or priority is not None:
            raise ApiValidationError(
                "submit(api.Request, ...) takes the request fields from "
                "the Request — don't also pass max_new_tokens/eos_id/"
                "priority kwargs")
        if request.sampling is not None \
                and request.sampling != self.config.sampling:
            raise ApiValidationError(
                f"request.sampling={request.sampling} != the engine's "
                f"compiled sampling={self.config.sampling} — the sampler "
                "is engine-wide (EngineConfig.sampling); route this "
                "request to a matching engine or drop request.sampling")
        if request.request_id is None:
            rid = self._next_rid
        else:
            rid = int(request.request_id)
        self._next_rid = max(self._next_rid, rid) + 1
        cb = None
        if stream is not None:
            def cb(_rid, token, done, _stream=stream, _n=[0]):
                _stream(api.StreamEvent(request_id=_rid, token=int(token),
                                        index=_n[0], done=bool(done)))
                _n[0] += 1
        req = _SchedRequest(rid=rid, prompt=request.prompt_ids,
                            max_new_tokens=request.max_new_tokens,
                            eos_id=request.eos_id, stream=cb,
                            priority=request.priority)
        self.scheduler.add(req, now=time.perf_counter())
        self._m_requests.inc(request_class=str(req.priority))
        return rid

    # -- the step loop ------------------------------------------------------

    def step(self) -> list[dict]:
        """Run one tick; returns the requests that finished during it."""
        tracer = self.tracer
        tick_t0 = tracer.now_us()           # tick span opens at schedule
        plan = self.scheduler.next_tick(now=time.perf_counter())
        if plan is None:
            return []
        tracer.complete_span("schedule", tick_t0)
        # COW copies queued by this tick's admissions land BEFORE the step
        # (prefill may overwrite the copy from the divergence point); the
        # pinned source page is released once the copy is issued — ops on
        # the pools are ordered by data dependency, re-allocation can only
        # happen at the next host-side tick
        for src, dst in self.scheduler.drain_copies():
            self.pools = self._copy_page(self.pools, jnp.int32(src),
                                         jnp.int32(dst))
            self.allocator.free([src])
        self.tick_widths.add(plan.width)
        n_tok = int(plan.n_tokens.sum())
        with tracer.span("step", width=plan.width, tokens=n_tok):
            self._rng, sub = jax.random.split(self._rng)
            step_args = (self.params, self.pools, jnp.asarray(plan.tokens),
                         jnp.asarray(self.scheduler.page_table()),
                         jnp.asarray(plan.start_pos),
                         jnp.asarray(plan.n_tokens), sub)
            if self.profiler is not None:
                sampled, _, self.pools = self.profiler.call(
                    "engine/tick_step", self._step, *step_args)
            else:
                sampled, _, self.pools = self._step(*step_args)
            sampled = np.asarray(sampled)   # device sync lands in the span
        self._m_ticks.inc(width=str(plan.width))
        self._m_tick_tokens.observe(n_tok)
        self._m_sampler_batch.observe(len(plan.samples))
        if self.has_attn:
            self._m_occupancy.observe(
                self.allocator.n_pages - 1 - self.allocator.n_free)
        with tracer.span("bookkeep"):
            finished = self.scheduler.complete_tick(
                plan, sampled, now=time.perf_counter())
            if self._zero_slots is not None:
                # zero the recurrent state of slots vacated this tick
                # (finish or preemption) unless a new occupant landed
                # already — the in-step position-0 reset covers that
                # occupant regardless
                mask = np.zeros(self.config.max_batch, bool)
                for i in self.scheduler.drain_freed_slots():
                    mask[i] = self.scheduler.slots[i] is None
                if mask.any():
                    self.pools = self._zero_slots(self.pools,
                                                  jnp.asarray(mask))
        for r in finished:
            self._m_finished.inc(request_class=str(r["priority"]))
            self._m_gen_tokens.inc(r["n_generated"])
        tracer.complete_span("tick", tick_t0, width=plan.width, tokens=n_tok)
        return finished

    def run(self, requests=None) -> dict:
        """Serve until the queue drains. ``requests``: optional iterable of
        ``api.Request`` values, ``(prompt, max_new_tokens)`` tuples (a
        documented convenience — converted without warning), or legacy
        ``submit``-kwarg dicts. Returns ``{"results": {rid: tokens},
        "completions": [api.Completion, ...], "stats": ...}``."""
        for r in (requests or []):
            if isinstance(r, api.Request):
                self.submit(r)
            elif isinstance(r, dict):
                kw = dict(r)
                stream = kw.pop("stream", None)
                self.submit(api.Request(**kw), stream=stream)
            else:
                prompt, gen = r
                self.submit(api.Request(prompt=prompt, max_new_tokens=gen))
        t0 = time.perf_counter()
        ticks0 = self.n_ticks
        chunks0 = self.scheduler.n_prefill_chunks
        tokens0 = self.scheduler.n_scheduled_tokens
        finished: list[dict] = []
        while self.scheduler.has_work():
            finished.extend(self.step())
        wall = time.perf_counter() - t0
        stats = self._stats(finished, wall)
        # per-run counters (the engine object is reusable across runs)
        stats["n_ticks"] = self.n_ticks - ticks0
        stats["n_prefill_chunks"] = \
            self.scheduler.n_prefill_chunks - chunks0
        stats["n_scheduled_tokens"] = \
            self.scheduler.n_scheduled_tokens - tokens0
        return {"results": {r["rid"]: r["tokens"] for r in finished},
                "completions": [Completion.from_record(r) for r in finished],
                "stats": stats}

    def _stats(self, finished: list[dict], wall: float) -> dict:
        """Throughput/latency summary of a drained run, with per-priority-
        class SLO accounting (p50/p95 TTFT + latency per class) and the
        prefix-cache hit rate. Percentiles over an empty record set are
        ``None`` (see ``obs.metrics.pct``), never a fabricated 0.0."""
        n_new = sum(r["n_generated"] for r in finished)

        def slo(records) -> dict:
            return slo_summary(
                (r["t_first"] - r["t_submit"] for r in records
                 if r["t_first"] is not None),
                (r["t_done"] - r["t_submit"] for r in records),
                len(records),
                n_preempted=sum(r["n_preempted"] for r in records))

        stats = {
            "n_requests": len(finished),
            "n_generated": int(n_new),
            "n_prompt": int(sum(r["n_prompt"] for r in finished)),
            "n_cached_tokens": int(sum(r["n_cached"] for r in finished)),
            "n_preemptions": self.scheduler.n_preemptions,
            "prefix_hit_rate": (self.prefix_cache.hit_rate
                                if self.prefix_cache is not None else 0.0),
            "kv_page_bytes": self.pool_bytes["kv_page_bytes"],
            "state_slot_bytes": self.pool_bytes["state_slot_bytes"],
            "wall_s": wall,
            "tok_s": n_new / wall if wall > 0 else 0.0,
            **slo(finished),
            "by_class": {c: slo([r for r in finished if r["priority"] == c])
                         for c in sorted({r["priority"] for r in finished})},
        }
        return stats
