"""The typed request API: the single serving contract.

Before this module the request surface was scattered kwargs —
``temperature``/``top_k``/``top_p`` on ``generate()``, positional
``(prompt, max_new_tokens, eos_id, stream, priority)`` on
``ServeEngine.submit``, and an ad-hoc JSON schema in ``launch/serve
--requests`` — with nothing a router could serialize. These frozen
dataclasses are now the one contract used everywhere:

* ``SamplingParams`` — how to turn logits into tokens (greedy by default).
  Consumed by ``serve.step.generate`` / ``make_sampler`` and (engine-wide)
  by ``EngineConfig.sampling``.
* ``Request`` — one serving request: prompt ids, generation budget, stop
  condition, priority class, optional per-request sampling.
* ``StreamEvent`` — one generated token in flight (streaming callbacks and
  the router's wire format).
* ``Completion`` — the finished request: tokens plus the SLO accounting
  (TTFT / latency stamps, cache hits, preemption + re-dispatch counts,
  which replica served it).

Every type round-trips through plain-dict JSON (``to_json``/``from_json``)
so the same value crosses the request-file boundary, the router wire, and
the Python API unchanged. ``from_json`` validates eagerly with actionable
messages (unknown key with a did-you-mean, bad priority type, out-of-range
sampling) instead of KeyErrors deep in the scheduler.

Legacy surfaces keep working through shims that warn once per call-site
(``merge_legacy_sampling``); new code should construct these types
directly. This module depends only on numpy — it is importable on the
router wire side without pulling in jax.
"""
from __future__ import annotations

import dataclasses
import difflib
import warnings
from typing import Optional

import numpy as np

# canonical class names for CLIs / request files (any int >= 0 is valid)
PRIORITY_CLASSES = {"interactive": 0, "standard": 1, "batch": 2}


class ApiValidationError(ValueError):
    """A request/params value failed validation. The message is written to
    be actionable: it names the offending field, the bad value, and what
    would have been accepted."""


def resolve_priority(p) -> int:
    """'interactive' / 'standard' / 'batch' or any int >= 0."""
    if isinstance(p, str):
        try:
            return PRIORITY_CLASSES[p]
        except KeyError:
            raise ApiValidationError(
                f"unknown priority class {p!r} — one of "
                f"{sorted(PRIORITY_CLASSES)} or an int >= 0") from None
    if isinstance(p, bool) or not isinstance(p, (int, np.integer)):
        raise ApiValidationError(
            f"priority must be a class name {sorted(PRIORITY_CLASSES)} or "
            f"an int >= 0, got {type(p).__name__} {p!r}")
    p = int(p)
    if p < 0:
        raise ApiValidationError(f"priority must be >= 0, got {p}")
    return p


_warned: set = set()


def _warn_once(key: str, msg: str) -> None:
    """One DeprecationWarning per call-site key per process — legacy shims
    stay usable without drowning logs."""
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, DeprecationWarning, stacklevel=3)


def _check_keys(d: dict, allowed: tuple, what: str) -> None:
    for k in d:
        if k not in allowed:
            hint = difflib.get_close_matches(str(k), allowed, n=1)
            hint = f" — did you mean {hint[0]!r}?" if hint else ""
            raise ApiValidationError(
                f"{what}: unknown key {k!r}{hint} (allowed: "
                f"{', '.join(allowed)})")


def _int_field(d: dict, key: str, what: str, default=None, minimum=None):
    v = d.get(key, default)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, np.integer)):
        raise ApiValidationError(
            f"{what}: {key!r} must be an int, got {type(v).__name__} {v!r}")
    v = int(v)
    if minimum is not None and v < minimum:
        raise ApiValidationError(f"{what}: {key!r} must be >= {minimum}, "
                                 f"got {v}")
    return v


# ---------------------------------------------------------------------------
# SamplingParams
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How logits become tokens. ``temperature == 0`` is greedy argmax
    (the default, and the only mode with per-token parity guarantees);
    otherwise sample from ``softmax(logits / temperature)`` after optional
    top-k truncation (``top_k > 0``) then nucleus filtering
    (``top_p < 1``)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    _FIELDS = ("temperature", "top_k", "top_p")

    def __post_init__(self):
        if not (self.temperature >= 0.0):
            raise ApiValidationError(
                f"temperature must be >= 0 (0 = greedy), got "
                f"{self.temperature!r}")
        if int(self.top_k) != self.top_k or self.top_k < 0:
            raise ApiValidationError(
                f"top_k must be an int >= 0 (0 = off), got {self.top_k!r}")
        if not (0.0 < self.top_p <= 1.0):
            raise ApiValidationError(
                f"top_p must be in (0, 1] (1 = off), got {self.top_p!r}")
        object.__setattr__(self, "temperature", float(self.temperature))
        object.__setattr__(self, "top_k", int(self.top_k))
        object.__setattr__(self, "top_p", float(self.top_p))

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def to_json(self) -> dict:
        return {"temperature": self.temperature, "top_k": self.top_k,
                "top_p": self.top_p}

    @classmethod
    def from_json(cls, d: dict, what: str = "sampling") -> "SamplingParams":
        if not isinstance(d, dict):
            raise ApiValidationError(
                f"{what}: expected an object like "
                f'{{"temperature": 0.7, "top_k": 40, "top_p": 0.9}}, '
                f"got {type(d).__name__} {d!r}")
        _check_keys(d, cls._FIELDS, what)
        try:
            return cls(**d)
        except ApiValidationError as e:
            raise ApiValidationError(f"{what}: {e}") from None


def merge_legacy_sampling(sampling: Optional[SamplingParams], where: str,
                          temperature=None, top_k=None,
                          top_p=None) -> SamplingParams:
    """The deprecation shim behind every migrated call site: fold loose
    ``temperature``/``top_k``/``top_p`` kwargs into a ``SamplingParams``,
    warning once per ``where``. Passing both the typed object and a legacy
    kwarg is a hard error (silently preferring one would hide bugs)."""
    legacy = {k: v for k, v in (("temperature", temperature),
                                ("top_k", top_k), ("top_p", top_p))
              if v is not None}
    if not legacy:
        return sampling if sampling is not None else SamplingParams()
    if sampling is not None:
        raise ApiValidationError(
            f"{where}: got both sampling={sampling} and legacy kwarg(s) "
            f"{sorted(legacy)} — move the values into SamplingParams")
    _warn_once(where, f"{where}: loose {sorted(legacy)} kwargs are "
                      "deprecated; pass sampling=SamplingParams(...)")
    return SamplingParams(**legacy)


# ---------------------------------------------------------------------------
# Request
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request — the unit the engine admits and the router
    dispatches. ``prompt`` is stored as a tuple of ints (hashable,
    JSON-clean); ``prompt_ids`` hands back the int32 array the model eats.
    ``sampling=None`` means "the engine's configured sampling" — a request
    carrying explicit sampling must match the engine it lands on (the
    engine's sampler is compiled engine-wide; see ``EngineConfig``).
    ``request_id`` is assigned by the engine/router at submission when
    None."""
    prompt: tuple
    max_new_tokens: int
    eos_id: Optional[int] = None
    priority: int = PRIORITY_CLASSES["standard"]
    sampling: Optional[SamplingParams] = None
    request_id: Optional[int] = None

    _FIELDS = ("prompt", "max_new_tokens", "eos_id", "priority", "sampling",
               "request_id")

    def __post_init__(self):
        prompt = self.prompt
        if isinstance(prompt, np.ndarray):
            prompt = prompt.ravel().tolist()
        try:
            prompt = tuple(int(t) for t in prompt)
        except (TypeError, ValueError):
            raise ApiValidationError(
                f"prompt must be a sequence of token ids, got "
                f"{type(self.prompt).__name__}") from None
        if len(prompt) < 1:
            raise ApiValidationError("prompt must be non-empty (the model "
                                     "needs at least one token to prefill)")
        object.__setattr__(self, "prompt", prompt)
        if int(self.max_new_tokens) != self.max_new_tokens \
                or self.max_new_tokens < 1:
            raise ApiValidationError(
                f"max_new_tokens must be an int >= 1, got "
                f"{self.max_new_tokens!r}")
        object.__setattr__(self, "max_new_tokens", int(self.max_new_tokens))
        object.__setattr__(self, "priority",
                           resolve_priority(self.priority))
        if self.eos_id is not None:
            object.__setattr__(self, "eos_id", int(self.eos_id))
        if self.sampling is not None \
                and not isinstance(self.sampling, SamplingParams):
            object.__setattr__(self, "sampling",
                               SamplingParams.from_json(self.sampling))

    @property
    def prompt_ids(self) -> np.ndarray:
        return np.asarray(self.prompt, np.int32)

    def to_json(self) -> dict:
        d = {"prompt": list(self.prompt),
             "max_new_tokens": self.max_new_tokens}
        if self.eos_id is not None:
            d["eos_id"] = self.eos_id
        if self.priority != PRIORITY_CLASSES["standard"]:
            d["priority"] = self.priority
        if self.sampling is not None:
            d["sampling"] = self.sampling.to_json()
        if self.request_id is not None:
            d["request_id"] = self.request_id
        return d

    @classmethod
    def from_json(cls, d: dict, what: str = "request") -> "Request":
        if not isinstance(d, dict):
            raise ApiValidationError(
                f"{what}: expected an object like "
                f'{{"prompt": [1, 2, 3], "max_new_tokens": 16}}, got '
                f"{type(d).__name__} {d!r}")
        _check_keys(d, cls._FIELDS, what)
        if "prompt" not in d:
            raise ApiValidationError(f"{what}: missing required key "
                                     "'prompt' (a list of token ids)")
        if "max_new_tokens" not in d:
            raise ApiValidationError(f"{what}: missing required key "
                                     "'max_new_tokens' (int >= 1)")
        kw = dict(d)
        if "sampling" in kw and kw["sampling"] is not None:
            kw["sampling"] = SamplingParams.from_json(kw["sampling"],
                                                      f"{what}.sampling")
        try:
            return cls(**kw)
        except ApiValidationError as e:
            raise ApiValidationError(f"{what}: {e}") from None


# ---------------------------------------------------------------------------
# StreamEvent / Completion
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One generated token, as streamed: ``index`` is its 0-based position
    in the generated sequence, ``done`` marks the final token, ``replica``
    names the serving replica under the router (None on a bare engine)."""
    request_id: int
    token: int
    index: int
    done: bool
    replica: Optional[int] = None

    _FIELDS = ("request_id", "token", "index", "done", "replica")

    def to_json(self) -> dict:
        d = {"request_id": self.request_id, "token": self.token,
             "index": self.index, "done": self.done}
        if self.replica is not None:
            d["replica"] = self.replica
        return d

    @classmethod
    def from_json(cls, d: dict, what: str = "stream_event") -> "StreamEvent":
        _check_keys(d, cls._FIELDS, what)
        try:
            return cls(**d)
        except TypeError as e:
            raise ApiValidationError(f"{what}: {e}") from None


@dataclasses.dataclass(frozen=True)
class Completion:
    """A finished request: the generated tokens plus the per-request SLO
    record. Timing stamps are ``time.perf_counter`` values on the serving
    host; ``ttft_s``/``latency_s`` are the derived SLO numbers. ``replica``
    is the replica that produced the FINAL token (requests re-dispatched
    after a replica failure finish elsewhere; ``n_redispatched`` counts
    those moves, ``n_preempted`` counts in-engine preemptions)."""
    request_id: int
    tokens: tuple
    n_prompt: int
    priority: int = PRIORITY_CLASSES["standard"]
    n_cached: int = 0
    n_preempted: int = 0
    n_redispatched: int = 0
    replica: Optional[int] = None
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: float = 0.0

    _FIELDS = ("request_id", "tokens", "n_prompt", "priority", "n_cached",
               "n_preempted", "n_redispatched", "replica", "t_submit",
               "t_first", "t_done")

    def __post_init__(self):
        tokens = self.tokens
        if isinstance(tokens, np.ndarray):
            tokens = tokens.ravel().tolist()
        object.__setattr__(self, "tokens", tuple(int(t) for t in tokens))

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def token_ids(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    @property
    def ttft_s(self) -> Optional[float]:
        return (None if self.t_first is None
                else self.t_first - self.t_submit)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    def to_json(self) -> dict:
        return {f: getattr(self, f) for f in self._FIELDS
                if f != "tokens"} | {"tokens": list(self.tokens)}

    @classmethod
    def from_json(cls, d: dict, what: str = "completion") -> "Completion":
        _check_keys(d, cls._FIELDS, what)
        try:
            return cls(**d)
        except TypeError as e:
            raise ApiValidationError(f"{what}: {e}") from None

    @classmethod
    def from_record(cls, rec: dict, *, request_id: Optional[int] = None,
                    replica: Optional[int] = None) -> "Completion":
        """Build from a scheduler finish record (``Scheduler._finish``)."""
        return cls(request_id=rec["rid"] if request_id is None
                   else request_id,
                   tokens=tuple(int(t) for t in rec["tokens"]),
                   n_prompt=rec["n_prompt"], priority=rec["priority"],
                   n_cached=rec["n_cached"],
                   n_preempted=rec["n_preempted"], replica=replica,
                   t_submit=rec["t_submit"], t_first=rec["t_first"],
                   t_done=rec["t_done"])


# ---------------------------------------------------------------------------
# Request files (launch/serve --requests, benchmark mixes)
# ---------------------------------------------------------------------------

_ENTRY_KEYS = ("prompt", "prompt_len", "gen", "max_new_tokens", "eos_id",
               "priority", "sampling", "request_id")


def normalize_request_entry(entry, index: int, *, default_gen: int,
                            default_priority=PRIORITY_CLASSES["standard"],
                            ) -> dict:
    """Validate one request-file entry and normalize it to canonical keys.

    The file schema is the ``Request`` JSON schema plus two conveniences:
    ``prompt_len`` (serve a seeded random prompt of that length — exactly
    one of ``prompt``/``prompt_len`` must be present) and ``gen`` as the
    historical alias of ``max_new_tokens``. Returns a dict with keys
    ``prompt`` (list | None), ``prompt_len`` (int | None),
    ``max_new_tokens``, ``eos_id``, ``priority`` (resolved int), and
    ``sampling`` (SamplingParams | None). Raises ``ApiValidationError``
    naming ``requests[index]`` on any problem.
    """
    what = f"requests[{index}]"
    if not isinstance(entry, dict):
        raise ApiValidationError(
            f"{what}: each entry must be an object like "
            f'{{"prompt_len": 16, "max_new_tokens": 8}}, got '
            f"{type(entry).__name__} {entry!r}")
    _check_keys(entry, _ENTRY_KEYS, what)
    if "gen" in entry and "max_new_tokens" in entry:
        raise ApiValidationError(
            f"{what}: 'gen' is the legacy alias of 'max_new_tokens' — "
            "pass one, not both")
    gen = _int_field(entry, "max_new_tokens", what, minimum=1)
    if gen is None:
        gen = _int_field(entry, "gen", what, minimum=1)
    if gen is None:
        gen = int(default_gen)
    if ("prompt" in entry) == ("prompt_len" in entry):
        raise ApiValidationError(
            f"{what}: exactly one of 'prompt' (explicit token ids) or "
            "'prompt_len' (seeded random prompt) is required")
    prompt = entry.get("prompt")
    if prompt is not None:
        try:
            prompt = [int(t) for t in prompt]
        except (TypeError, ValueError):
            raise ApiValidationError(
                f"{what}: 'prompt' must be a list of token ids, got "
                f"{prompt!r}") from None
        if not prompt:
            raise ApiValidationError(f"{what}: 'prompt' must be non-empty")
    sampling = entry.get("sampling")
    if sampling is not None:
        sampling = SamplingParams.from_json(sampling, f"{what}.sampling")
    try:
        priority = resolve_priority(entry.get("priority", default_priority))
    except ApiValidationError as e:
        raise ApiValidationError(f"{what}: {e}") from None
    return {"prompt": prompt,
            "prompt_len": _int_field(entry, "prompt_len", what, minimum=1),
            "max_new_tokens": gen,
            "eos_id": _int_field(entry, "eos_id", what, minimum=0),
            "priority": priority,
            "sampling": sampling,
            "request_id": _int_field(entry, "request_id", what, minimum=0)}


def parse_request_file(spec, *, default_gen: int,
                       default_priority=PRIORITY_CLASSES["standard"],
                       ) -> list:
    """Validate a whole ``--requests`` JSON document (a list of entries).
    Returns the normalized entry dicts (see ``normalize_request_entry``);
    the caller materializes ``prompt_len`` entries into seeded prompts."""
    if not isinstance(spec, list):
        raise ApiValidationError(
            "request file must be a JSON list of request objects, got "
            f"{type(spec).__name__}")
    if not spec:
        raise ApiValidationError("request file is empty — nothing to serve")
    return [normalize_request_entry(e, i, default_gen=default_gen,
                                    default_priority=default_priority)
            for i, e in enumerate(spec)]
