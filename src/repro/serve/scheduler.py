"""Request scheduler for the continuous-batching engine: FCFS admission
under a token budget, chunked prefill interleaved with decode, slot
recycling on EOS/max-len.

Scheduling is entirely host-side and shape-stable: every tick produces a
``TickPlan`` whose arrays are ``(capacity, width)`` with ``width`` one of 1
(pure-decode tick), ``prefill_chunk`` (a tick that advances at least one
prompt) or the optional ``first_chunk`` jumbo width (a tick granting a long
prompt its oversized FIRST chunk) — so the engine's jitted mixed step
compiles at most three times and the request mix only changes *data*.

The tick rules:

* **Admission** is FCFS. A waiting request is admitted when a slot is free
  and its worst-case page count (``pages_for(prompt + max_new)``) can be
  reserved up front — so a running request can never run out of pages
  mid-flight and no preemption is ever needed. Pages are an
  attention-layer resource: for pure-recurrent models (``reserve_pages=
  False``) the slot-indexed state pools are O(1) per slot and admission is
  page-free — a free slot is the only requirement.
* **Decode first.** Every running slot in the decode phase gets its 1 token
  each tick, off the top of the token budget — new prompts never stall
  running requests.
* **Chunked prefill** spends the remaining budget: prompts are consumed in
  chunks of up to ``prefill_chunk`` tokens, FCFS by admission order, so a
  32k prompt prefills across many ticks while decode slots keep streaming.
* **Jumbo first chunk** (optional, ``first_chunk > prefill_chunk``): a
  prompt longer than ``prefill_chunk`` gets its FIRST chunk at the jumbo
  width, then falls back to regular chunks — a hybrid schedule that keeps
  TTFT from being paced by the steady-state chunk size while bounding the
  compiled widths at three.
* **Slot recycling**: a request finishes on EOS or ``max_new_tokens``; its
  pages return to the free list and its slot is immediately re-admittable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serve.paged_kv import PageAllocator, pages_for


@dataclasses.dataclass
class Request:
    """One serving request. ``prompt`` is a 1D int32 token array;
    ``stream`` (optional) is called as ``stream(rid, token, done)`` for
    every generated token — the engine's per-request streaming callback."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    stream: Optional[Callable] = None


@dataclasses.dataclass
class _Slot:
    """Serving state of one admitted request (one engine slot)."""
    req: Request
    pages: list
    n_prefilled: int = 0
    generated: Optional[list] = None
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: Optional[float] = None

    def __post_init__(self):
        if self.generated is None:
            self.generated = []

    @property
    def prompt_done(self) -> bool:
        return self.n_prefilled >= len(self.req.prompt)

    @property
    def ctx_len(self) -> int:
        """Positions written to the KV cache so far."""
        return self.n_prefilled + max(len(self.generated) - 1, 0)


@dataclasses.dataclass
class TickPlan:
    """One tick's shape-stable batch: (capacity, width) tokens plus per-slot
    start positions / valid-token counts (0 = inactive slot)."""
    width: int
    tokens: np.ndarray       # (capacity, width) int32
    start_pos: np.ndarray    # (capacity,) int32
    n_tokens: np.ndarray     # (capacity,) int32
    samples: list = dataclasses.field(default_factory=list)
    # slots whose sampled token must be consumed this tick (finished a
    # prompt, or in decode phase); mid-prefill slots ignore the sample


class Scheduler:
    def __init__(self, capacity: int, prefill_chunk: int,
                 allocator: PageAllocator, page_size: int, max_pages: int,
                 token_budget: Optional[int] = None,
                 first_chunk: Optional[int] = None,
                 reserve_pages: bool = True):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, {prefill_chunk}")
        self.capacity = int(capacity)
        self.prefill_chunk = int(prefill_chunk)
        # jumbo width for the FIRST chunk of a long prompt (None/0 = off)
        self.first_chunk = int(first_chunk) if first_chunk else None
        if self.first_chunk is not None \
                and self.first_chunk <= self.prefill_chunk:
            raise ValueError(
                f"first_chunk {self.first_chunk} must exceed prefill_chunk "
                f"{self.prefill_chunk} (it is the jumbo width; use None to "
                "disable)")
        self.allocator = allocator
        self.page_size = int(page_size)
        self.max_pages = int(max_pages)
        # False for models with no attention layers: recurrent state is a
        # slot-indexed pool (O(1) per slot), so admission reserves nothing
        # and context length is not page-capped
        self.reserve_pages = bool(reserve_pages)
        # default: every slot can decode AND one full (jumbo) chunk can
        # prefill — without headroom for first_chunk the jumbo grant would
        # always clamp back to the regular width
        self.token_budget = int(
            token_budget or (capacity + (self.first_chunk or prefill_chunk)))
        if self.token_budget < max(capacity, prefill_chunk):
            raise ValueError(
                f"token_budget {self.token_budget} < "
                f"max(capacity={capacity}, prefill_chunk={prefill_chunk}) "
                "would starve decode or deadlock prefill")
        self.waiting: deque[tuple[Request, float]] = deque()
        self.slots: list[Optional[_Slot]] = [None] * self.capacity
        self.n_prefill_chunks = 0          # chunks actually scheduled
        self.n_scheduled_tokens = 0

    # -- admission ----------------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Worst-case page reservation — 0 when pages aren't the resource
        (pure-recurrent models: admission is slot-only)."""
        if not self.reserve_pages:
            return 0
        return pages_for(len(req.prompt) + req.max_new_tokens,
                         self.page_size)

    def add(self, req: Request, now: float = 0.0) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: need a non-empty prompt "
                             "and max_new_tokens >= 1")
        need = self._pages_needed(req)
        if need > self.max_pages or need > self.allocator.n_pages - 1:
            raise ValueError(
                f"request {req.rid} needs {need} pages "
                f"(prompt {len(req.prompt)} + max_new {req.max_new_tokens}) "
                f"but the engine caps at {self.max_pages} pages/slot and "
                f"{self.allocator.n_pages - 1} total")
        self.waiting.append((req, now))

    def _admit(self, now: float) -> None:
        for i in range(self.capacity):
            if not self.waiting:
                return
            if self.slots[i] is not None:
                continue
            req, t_submit = self.waiting[0]
            need = self._pages_needed(req)
            if need > self.allocator.n_free:
                return                      # FCFS: don't admit around the head
            self.waiting.popleft()
            self.slots[i] = _Slot(req=req,
                                  pages=self.allocator.alloc(need),
                                  t_submit=t_submit, t_admit=now)

    # -- tick construction --------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def page_table(self) -> np.ndarray:
        table = np.zeros((self.capacity, self.max_pages), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                table[i, :len(s.pages)] = s.pages
        return table

    def next_tick(self, now: float = 0.0) -> Optional[TickPlan]:
        """Admit waiting requests, then plan one tick. None = idle."""
        self._admit(now)
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return None
        budget = self.token_budget
        decode = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and s.prompt_done]
        prefill = [(i, s) for i, s in enumerate(self.slots)
                   if s is not None and not s.prompt_done]
        budget -= len(decode)               # decode never stalls
        grants: list[tuple[int, _Slot, int]] = []
        for i, s in prefill:                # FCFS by slot admission
            chunk = self.prefill_chunk
            if (self.first_chunk is not None and s.n_prefilled == 0
                    and len(s.req.prompt) > self.prefill_chunk):
                chunk = self.first_chunk    # jumbo first chunk (TTFT)
            c = min(chunk, len(s.req.prompt) - s.n_prefilled, max(budget, 0))
            grants.append((i, s, c))
            budget -= c
        # width stays one of {1, prefill_chunk, first_chunk}: a jumbo grant
        # clamped (by budget or prompt length) to <= prefill_chunk rides the
        # regular width, so no fourth shape ever compiles
        max_grant = max((c for _, _, c in grants), default=0)
        if max_grant == 0:
            width = 1
        elif max_grant <= self.prefill_chunk:
            width = self.prefill_chunk
        else:
            width = self.first_chunk

        tokens = np.zeros((self.capacity, width), np.int32)
        start = np.zeros(self.capacity, np.int32)
        n_tok = np.zeros(self.capacity, np.int32)
        samples = []
        for i, s in decode:
            tokens[i, 0] = s.generated[-1]
            start[i] = s.ctx_len
            n_tok[i] = 1
            samples.append(i)
        for i, s, c in grants:
            if c <= 0:
                continue                    # budget-deferred this tick
            tokens[i, :c] = s.req.prompt[s.n_prefilled:s.n_prefilled + c]
            start[i] = s.n_prefilled
            n_tok[i] = c
            self.n_prefill_chunks += 1
            if s.n_prefilled + c >= len(s.req.prompt):
                samples.append(i)           # prompt completes: sample now
        self.n_scheduled_tokens += int(n_tok.sum())
        return TickPlan(width=width, tokens=tokens, start_pos=start,
                        n_tokens=n_tok, samples=samples)

    # -- tick completion ----------------------------------------------------

    def complete_tick(self, plan: TickPlan, sampled: np.ndarray,
                      now: float = 0.0) -> list[dict]:
        """Feed back the sampled tokens; returns records of requests that
        finished this tick (their slots and pages are already recycled).
        The scheduler retains nothing about finished requests — the caller
        owns the records, so a long-lived engine stays O(capacity)."""
        finished: list[dict] = []
        for i in range(self.capacity):
            s = self.slots[i]
            if s is None or plan.n_tokens[i] == 0:
                continue
            if not s.prompt_done:
                s.n_prefilled += int(plan.n_tokens[i])
            if i not in plan.samples:
                continue                    # mid-prefill: ignore the sample
            tok = int(sampled[i])
            if s.t_first is None:
                s.t_first = now
            s.generated.append(tok)
            done = (len(s.generated) >= s.req.max_new_tokens
                    or (s.req.eos_id is not None and tok == s.req.eos_id))
            if s.req.stream is not None:
                s.req.stream(s.req.rid, tok, done)
            if done:
                finished.append(self._finish(i, now))
        return finished

    def _finish(self, i: int, now: float) -> dict:
        s = self.slots[i]
        self.allocator.free(s.pages)
        self.slots[i] = None
        return {
            "rid": s.req.rid,
            "slot": i,                      # for engine-side state recycling
            "tokens": np.asarray(s.generated, np.int32),
            "n_prompt": len(s.req.prompt),
            "n_generated": len(s.generated),
            "t_submit": s.t_submit, "t_admit": s.t_admit,
            "t_first": s.t_first, "t_done": now,
        }
