"""Production request scheduler for the continuous-batching engine:
priority classes with preempt-and-requeue, optimistic admission with lazy
page allocation, radix-tree prefix-cache integration, SLO-aware per-class
token-budget shares, chunked prefill interleaved with decode.

Scheduling is entirely host-side and shape-stable: every tick produces a
``TickPlan`` whose arrays are ``(capacity, width)`` with ``width`` one of 1
(pure-decode tick), ``prefill_chunk`` (a tick that advances at least one
prompt) or the optional ``first_chunk`` jumbo width — request churn,
preemption, and prefix-cache hits only ever change array *data*, so the
engine's jitted mixed step compiles at most three times.

The request lifecycle (the preemption state machine):

    WAITING --admit--> PREFILLING --prompt done--> DECODING --EOS/max--> DONE
       ^                   |                           |
       '---- preempt ------'----------ditto------------'

* **Admission is optimistic, by priority class.** Requests carry an int
  ``priority`` (0 = most important; see ``PRIORITY_CLASSES``). A waiting
  request is admitted as soon as a slot is free — no worst-case page
  reservation. Pages are allocated lazily, tick by tick, for the tokens
  actually being written. Within a class admission is FCFS; across
  classes, more important first. If every slot is busy and the head of a
  waiting class is strictly more important than some running request, the
  least-important (then youngest) running slot is preempted to make room.
* **Preempt-and-requeue.** A preempted request's pages are released (its
  prefix-cached pages survive in the radix tree — the tree holds its own
  reference), its generated-so-far tokens are kept, and it re-enters the
  FRONT of its class queue. On re-admission its prompt *plus* the tokens
  it already generated are re-prefilled as one sequence ("seq"); with the
  prefix cache on, the prompt part is typically still cached, so resume
  costs only the generated suffix. Greedy decoding makes the resumed
  request's remaining tokens match the uninterrupted run token-for-token.
* **Page-shortfall preemption.** When a tick needs pages and the free
  list is dry, cold prefix-cache pages are evicted first (LRU leaves the
  tree is the sole owner of); if still short, the least-important
  youngest page-holding slot is preempted — possibly the needy slot
  itself (its grant is then deferred to a later tick).
* **Decode first.** Every running slot in the decode phase gets its 1
  token each tick, off the top of the token budget, most-important first.
* **SLO-aware prefill shares.** The remaining budget is split across the
  priority classes that have prefill demand, proportionally to
  ``class_shares`` (default: class c gets weight 2^-c), leftover spilling
  to the most important class — so batch-class prefill can never starve
  interactive TTFT, but still makes progress under load.
* **Prefix cache.** At admission the request's seq is matched against the
  radix tree: fully cached pages are mapped into the page table (shared,
  refcounted), a mid-page match becomes a COW copy (``drain_copies``),
  and ``n_prefilled`` starts at the cached length. At prompt completion
  the request's immutable prompt pages are inserted for future requests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serve.api import (PRIORITY_CLASSES,  # noqa: F401 (re-export)
                             resolve_priority)
from repro.serve.paged_kv import PageAllocator, pages_for
from repro.serve.prefix_cache import PrefixCache


@dataclasses.dataclass
class Request:
    """One serving request. ``prompt`` is a 1D int32 token array;
    ``stream`` (optional) is called as ``stream(rid, token, done)`` for
    every generated token; ``priority`` is the scheduling class
    (0 = most important — see ``PRIORITY_CLASSES``)."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    stream: Optional[Callable] = None
    priority: int = 1


@dataclasses.dataclass
class _WaitEntry:
    """A queued (possibly preempted) request and the state that survives
    preemption: tokens generated so far, TTFT stamp, preemption count."""
    req: Request
    t_submit: float
    generated: list = dataclasses.field(default_factory=list)
    n_preempted: int = 0
    t_first: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    """Serving state of one admitted request (one engine slot). ``seq`` is
    the token sequence being prefilled: the prompt, plus — after a
    preemption — the tokens generated before it (regenerating the KV the
    preemption dropped)."""
    req: Request
    seq: np.ndarray
    pages: list
    admit_seq: int                      # admission stamp (victim tiebreak)
    n_cached: int = 0                   # seq tokens served by prefix cache
    n_prefilled: int = 0                # seq tokens done (incl. cached)
    generated: Optional[list] = None    # all generated (incl. pre-preempt)
    n_gen_at_admit: int = 0
    n_preempted: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: Optional[float] = None

    def __post_init__(self):
        if self.generated is None:
            self.generated = []

    @property
    def prompt_done(self) -> bool:
        return self.n_prefilled >= len(self.seq)

    @property
    def ctx_len(self) -> int:
        """Positions covered in the KV cache so far (cached + written)."""
        return self.n_prefilled + max(
            len(self.generated) - self.n_gen_at_admit - 1, 0)

    def sort_key(self) -> tuple:
        """Importance order: class first, oldest-admitted first."""
        return (self.req.priority, self.admit_seq)


@dataclasses.dataclass
class TickPlan:
    """One tick's shape-stable batch: (capacity, width) tokens plus per-slot
    start positions / valid-token counts (0 = inactive slot)."""
    width: int
    tokens: np.ndarray       # (capacity, width) int32
    start_pos: np.ndarray    # (capacity,) int32
    n_tokens: np.ndarray     # (capacity,) int32
    samples: list = dataclasses.field(default_factory=list)
    # slots whose sampled token must be consumed this tick (finished a
    # prompt, or in decode phase); mid-prefill slots ignore the sample


class Scheduler:
    def __init__(self, capacity: int, prefill_chunk: int,
                 allocator: PageAllocator, page_size: int, max_pages: int,
                 token_budget: Optional[int] = None,
                 first_chunk: Optional[int] = None,
                 paged: bool = True,
                 prefix_cache: Optional[PrefixCache] = None,
                 class_shares: Optional[dict] = None,
                 metrics=None, tracer=None):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, {prefill_chunk}")
        self.capacity = int(capacity)
        self.prefill_chunk = int(prefill_chunk)
        # jumbo width for the FIRST chunk of a long prompt (None/0 = off)
        self.first_chunk = int(first_chunk) if first_chunk else None
        if self.first_chunk is not None \
                and self.first_chunk <= self.prefill_chunk:
            raise ValueError(
                f"first_chunk {self.first_chunk} must exceed prefill_chunk "
                f"{self.prefill_chunk} (it is the jumbo width; use None to "
                "disable)")
        self.allocator = allocator
        self.page_size = int(page_size)
        self.max_pages = int(max_pages)
        # False for models with no attention layers: recurrent state is a
        # slot-indexed pool (O(1) per slot), so no pages are ever allocated
        # and context length is not page-capped
        self.paged = bool(paged)
        self.prefix_cache = prefix_cache
        if prefix_cache is not None and not self.paged:
            raise ValueError("prefix_cache shares KV *pages* — meaningless "
                             "for a page-free (pure-recurrent) scheduler")
        # per-class prefill budget weights; default: class c weighs 2^-c
        self.class_shares = dict(class_shares or {})
        # default: every slot can decode AND one full (jumbo) chunk can
        # prefill — without headroom for first_chunk the jumbo grant would
        # always clamp back to the regular width
        self.token_budget = int(
            token_budget or (capacity + (self.first_chunk or prefill_chunk)))
        if self.token_budget < max(capacity, prefill_chunk):
            raise ValueError(
                f"token_budget {self.token_budget} < "
                f"max(capacity={capacity}, prefill_chunk={prefill_chunk}) "
                "would starve decode or deadlock prefill")
        self.waiting: dict[int, deque] = {}      # class -> _WaitEntry deque
        self.slots: list[Optional[_Slot]] = [None] * self.capacity
        self._admit_clock = 0
        self._pending_copies: list[tuple[int, int]] = []   # (src, dst)
        self._freed_slots: set[int] = set()    # vacated by preempt/finish
        # scheduling counters live in the metrics registry — the engine
        # passes its own so stats / Prometheus read the same numbers; a
        # standalone scheduler gets a private live registry
        m = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._m_admissions = m.counter(
            "repro_sched_admissions_total",
            "requests admitted into a slot (resumed = after a preemption)",
            labelnames=("resumed",))
        self._m_preemptions = m.counter(
            "repro_sched_preemptions_total",
            "requests preempted and requeued")
        self._m_famine = m.counter(
            "repro_sched_famine_ticks_total",
            "empty ticks emitted under total page famine")
        self._m_prefill_chunks = m.counter(
            "repro_sched_prefill_chunks_total", "prefill chunks scheduled")
        self._m_tokens = m.counter(
            "repro_sched_tokens_total",
            "tokens scheduled into ticks, by kind (prefill/decode)",
            labelnames=("kind",))
        self._m_cow = m.counter(
            "repro_sched_cow_copies_total",
            "copy-on-write page copies queued at admission")

    # -- counters (registry-backed; kept as the original attribute names) ---

    @property
    def n_prefill_chunks(self) -> int:
        return int(self._m_prefill_chunks.value())

    @property
    def n_scheduled_tokens(self) -> int:
        return int(self._m_tokens.total())

    @property
    def n_preemptions(self) -> int:
        return int(self._m_preemptions.value())

    # -- load (the router's least-loaded signal) ----------------------------

    @property
    def n_queued(self) -> int:
        """Requests admitted but not finished: waiting + in a slot."""
        return (sum(len(q) for q in self.waiting.values())
                + sum(s is not None for s in self.slots))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_reserved_pages(self) -> int:
        """KV pages currently held by admitted requests (excludes the
        prefix-cache tree's own references)."""
        return sum(len(s.pages) for s in self.slots if s is not None)

    # -- admission ----------------------------------------------------------

    def add(self, req: Request, now: float = 0.0) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: need a non-empty prompt "
                             "and max_new_tokens >= 1")
        req.priority = resolve_priority(req.priority)
        if self.paged:
            need = pages_for(len(req.prompt) + req.max_new_tokens,
                             self.page_size)
            if need > self.max_pages or need > self.allocator.n_pages - 1:
                raise ValueError(
                    f"request {req.rid} needs {need} pages "
                    f"(prompt {len(req.prompt)} + max_new "
                    f"{req.max_new_tokens}) but the engine caps at "
                    f"{self.max_pages} pages/slot and "
                    f"{self.allocator.n_pages - 1} total")
        self.waiting.setdefault(req.priority, deque()).append(
            _WaitEntry(req=req, t_submit=now))
        self.tracer.request_submit(req.rid, req.priority, len(req.prompt))

    def _waiting_classes(self) -> list[int]:
        return sorted(c for c, q in self.waiting.items() if q)

    def _admit_into(self, i: int, now: float) -> None:
        """Admit the most important waiting request into free slot ``i``."""
        entry = self.waiting[self._waiting_classes()[0]].popleft()
        seq = np.asarray(entry.req.prompt, np.int32)
        if entry.generated:                # resume: regenerate dropped KV
            seq = np.concatenate([seq, np.asarray(entry.generated,
                                                  np.int32)])
        pages, n_cached = [], 0
        if self.prefix_cache is not None:
            pages, n_cached, cow_src = self.prefix_cache.match(seq)
            if cow_src is not None:
                # private copy of the partially matching boundary page
                dst = self._alloc_pages(1)
                if dst:
                    pages += dst
                    self._pending_copies.append((cow_src, dst[0]))
                    self._m_cow.inc()
                else:                      # no page for the copy: round the
                    n_cached = len(pages) * self.page_size   # match down
                    self.allocator.free([cow_src])
        self._admit_clock += 1
        self.slots[i] = _Slot(
            req=entry.req, seq=seq, pages=pages,
            admit_seq=self._admit_clock, n_cached=n_cached,
            n_prefilled=n_cached, generated=list(entry.generated),
            n_gen_at_admit=len(entry.generated),
            n_preempted=entry.n_preempted, t_submit=entry.t_submit,
            t_admit=now, t_first=entry.t_first)
        resumed = entry.n_preempted > 0
        self._m_admissions.inc(resumed=str(resumed).lower())
        self.tracer.request_admit(entry.req.rid, resumed, n_cached)

    def _admit(self, now: float) -> None:
        for i in range(self.capacity):
            if not self._waiting_classes():
                return
            if self.slots[i] is None:
                self._admit_into(i, now)
        # every slot busy: a strictly more important waiting request may
        # preempt the least-important (then youngest) running slot
        while True:
            classes = self._waiting_classes()
            if not classes:
                return
            occupied = [i for i, s in enumerate(self.slots) if s is not None]
            if len(occupied) < self.capacity:
                return                     # a slot freed up: next tick admits
            victim = max(occupied, key=lambda i: self.slots[i].sort_key())
            if self.slots[victim].req.priority <= classes[0]:
                return                     # nobody strictly less important
            self._preempt(victim, now)
            self._admit_into(victim, now)

    # -- preemption ---------------------------------------------------------

    def _preempt(self, i: int, now: float) -> None:
        """Release slot ``i``'s pages and requeue its request at the FRONT
        of its class (so it resumes as soon as resources allow)."""
        s = self.slots[i]
        self.allocator.free(s.pages)
        self.slots[i] = None
        self._freed_slots.add(i)
        self._m_preemptions.inc()
        self.tracer.request_preempt(s.req.rid)
        self.waiting.setdefault(s.req.priority, deque()).appendleft(
            _WaitEntry(req=s.req, t_submit=s.t_submit,
                       generated=list(s.generated),
                       n_preempted=s.n_preempted + 1, t_first=s.t_first))

    def _alloc_pages(self, n: int) -> list[int]:
        """Allocate ``n`` pages, evicting cold prefix-cache pages if the
        free list runs dry. Returns [] (not an exception) when short."""
        short = n - self.allocator.n_free
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict(short)
        if n > self.allocator.n_free:
            return []
        return self.allocator.alloc(n)

    def _ensure_pages(self, i: int, n_total: int, now: float) -> bool:
        """Grow slot ``i``'s page list to ``n_total`` pages, preempting
        less-important younger page-holders if eviction isn't enough.
        False = could not (slot may have preempted ITSELF and be gone)."""
        s = self.slots[i]
        while True:
            got = self._alloc_pages(n_total - len(s.pages))
            if got or n_total <= len(s.pages):
                s.pages += got
                return True
            victims = [j for j, v in enumerate(self.slots)
                       if v is not None and v.pages
                       and (j == i or v.sort_key() > s.sort_key())]
            if not victims:
                return False               # defer: nothing rightfully ours
            j = max(victims, key=lambda j: self.slots[j].sort_key())
            self._preempt(j, now)
            if j == i:
                return False               # preempted ourselves

    # -- tick construction --------------------------------------------------

    def has_work(self) -> bool:
        return bool(self._waiting_classes()) \
            or any(s is not None for s in self.slots)

    def page_table(self) -> np.ndarray:
        table = np.zeros((self.capacity, self.max_pages), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                table[i, :len(s.pages)] = s.pages
        return table

    def drain_copies(self) -> list[tuple[int, int]]:
        """COW copies queued by admissions since the last drain, as
        ``(src, dst)`` page pairs. The caller must copy ``src``'s pool
        content onto ``dst`` BEFORE running the tick's step (the step may
        write into ``dst``), then release the pinned source with
        ``allocator.free([src])``."""
        out, self._pending_copies = self._pending_copies, []
        return out

    def drain_freed_slots(self) -> set:
        """Slot indices vacated (preempt or finish) since the last drain —
        the engine zeroes their recurrent state, unless re-occupied
        already. Host-side hygiene; the in-step position-0 reset is the
        correctness invariant either way."""
        out, self._freed_slots = self._freed_slots, set()
        return out

    def _prefill_quota(self, prefill: list, budget: int) -> dict:
        """SLO shares: split the post-decode budget across the classes
        with prefill demand, proportional to ``class_shares`` (default
        2^-class), integer leftover to the most important class."""
        classes = sorted({s.req.priority for _, s in prefill})
        w = {c: float(self.class_shares.get(c, 2.0 ** -c)) for c in classes}
        tot = sum(w.values()) or 1.0
        quota = {c: int(budget * w[c] / tot) for c in classes}
        quota[classes[0]] += budget - sum(quota.values())
        return quota

    def next_tick(self, now: float = 0.0) -> Optional[TickPlan]:
        """Admit waiting requests, then plan one tick. None = idle."""
        self._admit(now)
        if all(s is None for s in self.slots):
            return None
        budget = self.token_budget
        decode = sorted(((i, s) for i, s in enumerate(self.slots)
                         if s is not None and s.prompt_done),
                        key=lambda t: t[1].sort_key())
        # decode never stalls: 1 token per decoding slot, off the top —
        # but lazily allocate the page its token lands in first
        decodes: list[tuple[int, _Slot]] = []
        for i, s in decode:
            if self.paged and not self._ensure_pages(
                    i, pages_for(s.ctx_len + 1, self.page_size), now):
                continue                   # deferred (or self-preempted)
            if self.slots[i] is s:         # survived any preemption round
                decodes.append((i, s))
        budget -= len(decodes)

        prefill = sorted(((i, s) for i, s in enumerate(self.slots)
                          if s is not None and not s.prompt_done),
                         key=lambda t: t[1].sort_key())
        grants: list[tuple[int, _Slot, int]] = []
        if prefill:
            quota = self._prefill_quota(prefill, max(budget, 0))
            for i, s in prefill:
                if self.slots[i] is not s:
                    continue               # preempted by an earlier grant
                chunk = self.prefill_chunk
                if (self.first_chunk is not None
                        and s.n_prefilled == s.n_cached
                        and len(s.seq) - s.n_cached > self.prefill_chunk):
                    chunk = self.first_chunk    # jumbo first chunk (TTFT)
                c = min(chunk, len(s.seq) - s.n_prefilled,
                        max(quota[s.req.priority], 0), max(budget, 0))
                if c > 0 and self.paged and not self._ensure_pages(
                        i, pages_for(s.n_prefilled + c, self.page_size),
                        now):
                    if self.slots[i] is not s:
                        continue           # self-preempted: grant dropped
                    # shrink to the pages already owned (page-aligned)
                    c = min(c, len(s.pages) * self.page_size
                            - s.n_prefilled)
                if c <= 0:
                    continue
                grants.append((i, s, c))
                quota[s.req.priority] -= c
                budget -= c
        # width stays one of {1, prefill_chunk, first_chunk}: a jumbo grant
        # clamped (by budget/shares/prompt length) to <= prefill_chunk rides
        # the regular width, so no fourth shape ever compiles
        max_grant = max((c for _, _, c in grants), default=0)
        if max_grant == 0:
            width = 1
        elif max_grant <= self.prefill_chunk:
            width = self.prefill_chunk
        else:
            width = self.first_chunk

        if not decodes and not grants:
            # pathological page famine: every slot deferred. Emit an empty
            # 1-wide plan so the engine loop keeps ticking (admission /
            # eviction may unblock the next tick).
            self._m_famine.inc()
            self.tracer.instant("famine_tick", cat="engine")
            return TickPlan(width=1,
                            tokens=np.zeros((self.capacity, 1), np.int32),
                            start_pos=np.zeros(self.capacity, np.int32),
                            n_tokens=np.zeros(self.capacity, np.int32))

        tokens = np.zeros((self.capacity, width), np.int32)
        start = np.zeros(self.capacity, np.int32)
        n_tok = np.zeros(self.capacity, np.int32)
        samples = []
        for i, s in decodes:
            tokens[i, 0] = s.generated[-1]
            start[i] = s.ctx_len
            n_tok[i] = 1
            samples.append(i)
        for i, s, c in grants:
            tokens[i, :c] = s.seq[s.n_prefilled:s.n_prefilled + c]
            start[i] = s.n_prefilled
            n_tok[i] = c
            self._m_prefill_chunks.inc()
            self.tracer.request_prefill_chunk(s.req.rid, c)
            if s.n_prefilled + c >= len(s.seq):
                samples.append(i)           # prompt completes: sample now
        if decodes:
            self._m_tokens.inc(len(decodes), kind="decode")
        n_prefill_tok = int(n_tok.sum()) - len(decodes)
        if n_prefill_tok:
            self._m_tokens.inc(n_prefill_tok, kind="prefill")
        return TickPlan(width=width, tokens=tokens, start_pos=start,
                        n_tokens=n_tok, samples=samples)

    # -- tick completion ----------------------------------------------------

    def complete_tick(self, plan: TickPlan, sampled: np.ndarray,
                      now: float = 0.0) -> list[dict]:
        """Feed back the sampled tokens; returns records of requests that
        finished this tick (their slots and pages are already recycled).
        The scheduler retains nothing about finished requests — the caller
        owns the records, so a long-lived engine stays O(capacity)."""
        finished: list[dict] = []
        for i in range(self.capacity):
            s = self.slots[i]
            if s is None or plan.n_tokens[i] == 0:
                continue
            if not s.prompt_done:
                s.n_prefilled += int(plan.n_tokens[i])
                if s.prompt_done and self.prefix_cache is not None:
                    # prompt pages are final now; cache the immutable ones
                    n_full = len(s.req.prompt) // self.page_size
                    self.prefix_cache.insert(s.req.prompt,
                                             s.pages[:n_full])
            if i not in plan.samples:
                continue                    # mid-prefill: ignore the sample
            tok = int(sampled[i])
            if s.t_first is None:
                s.t_first = now
                self.tracer.request_first_token(s.req.rid)
            s.generated.append(tok)
            self.tracer.request_decode(s.req.rid)
            done = (len(s.generated) >= s.req.max_new_tokens
                    or (s.req.eos_id is not None and tok == s.req.eos_id))
            if s.req.stream is not None:
                s.req.stream(s.req.rid, tok, done)
            if done:
                finished.append(self._finish(i, now))
        return finished

    def _finish(self, i: int, now: float) -> dict:
        s = self.slots[i]
        self.allocator.free(s.pages)
        self.slots[i] = None
        self._freed_slots.add(i)
        self.tracer.request_finish(s.req.rid)
        return {
            "rid": s.req.rid,
            "slot": i,                      # for engine-side state recycling
            "tokens": np.asarray(s.generated, np.int32),
            "n_prompt": len(s.req.prompt),
            "n_generated": len(s.generated),
            "priority": s.req.priority,
            "n_cached": s.n_cached,
            "n_preempted": s.n_preempted,
            "t_submit": s.t_submit, "t_admit": s.t_admit,
            "t_first": s.t_first, "t_done": now,
        }
