"""Multi-replica serving router: one asyncio front-end, N ``ServeEngine``
replicas.

The paper's compression ratios (3.5–5.4× smaller models) buy *replicas*:
every replica shares the same immutable compressed ``params`` tree (jax
arrays are read-only, so N replicas cost one copy of the weights) and owns
only its private slot resource pools — so the smaller the compressed model,
the more data-parallel engines fit on a host. The router is the layer that
turns that into aggregate tokens/s.

Architecture
------------

* Each replica is a **worker thread** owning one ``ServeEngine`` built from
  the same ``EngineConfig`` value. The jitted mixed step releases the GIL
  during XLA execution, so replicas overlap compute with each other and
  with the router's host-side bookkeeping.
* The router itself is **asyncio**: ``submit()`` dispatches an
  ``api.Request`` and returns a future ``api.Completion``; streaming
  callbacks receive ``api.StreamEvent`` (with ``replica`` set) in the event
  loop thread. Workers talk back via ``loop.call_soon_threadsafe`` only —
  all router state is mutated in the loop thread, no locks.
* **Dispatch** (``--route``):
  - ``prefix`` (default): rendezvous-hash (HRW) the prompt's leading
    page-aligned tokens over the healthy replicas, so requests sharing a
    system prompt land where the radix prefix cache already holds it —
    and replica death remaps only the dead replica's keys. Requests too
    short for a full page fall back to least-loaded; a busy preferred
    replica is waited on (bounded by backpressure), not diverted — a
    diverted request would cold-prefill the shared prefix anyway.
  - ``least-loaded``: queue depth + reserved KV pages, ties to the lowest
    replica index (deterministic).
  - ``round-robin``: modulo counter over healthy replicas (the control
    lane that destroys prefix affinity).
* **Backpressure**: at most ``max_inflight`` router-side requests per
  replica (default ``2 * max_batch``); ``submit()`` awaits capacity.
* **Health**: a worker that raises marks itself dead immediately; a
  monitor task also catches hard-dead threads and heartbeat stalls
  (``stall_timeout_s`` with work pending). A failed replica is drained:
  its queued + running requests are **re-dispatched** as resume requests
  (original prompt + tokens generated so far, reduced budget) — greedy
  decoding makes the stitched stream match an uninterrupted run
  token-for-token. Stale events from the old dispatch are dropped by an
  epoch check, so a re-generated token is streamed exactly once.
"""
from __future__ import annotations

import asyncio
import hashlib
import queue
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry, slo_summary
from repro.serve import api
from repro.serve.api import ApiValidationError, Completion, Request, StreamEvent
from repro.serve.engine import EngineConfig, ServeEngine

ROUTE_POLICIES = ("prefix", "least-loaded", "round-robin")

_STOP = object()


class ReplicaFailed(RuntimeError):
    """Every replica is dead — the request cannot be served."""


class _Replica:
    """One worker thread + its engine + the router's view of its load."""

    def __init__(self, idx: int, engine: ServeEngine, m_done, m_tokens):
        self.idx = idx
        self.engine = engine
        self.inbox: queue.Queue = queue.Queue()
        self.thread: Optional[threading.Thread] = None   # set by start()
        self.hb = time.monotonic()        # worker heartbeat (stall detection)
        self.error: Optional[BaseException] = None
        self.failed = False               # set by the router (loop thread)
        self.inflight = 0                 # router-side dispatched - finished
        # replica-labeled series in the router's registry — the original
        # per-replica int counters, readable as the same attribute names
        self._m_done = m_done
        self._m_tokens = m_tokens
        self._post: Optional[Callable] = None   # set by Router.start
        self._epochs: dict[int, int] = {}       # rid -> dispatch epoch

    # -- worker thread ------------------------------------------------------

    def _run(self):
        try:
            while True:
                self.hb = time.monotonic()
                busy = self.engine.scheduler.has_work()
                try:
                    item = (self.inbox.get_nowait() if busy
                            else self.inbox.get(timeout=0.02))
                except queue.Empty:
                    item = None
                while item is not None:
                    if item is _STOP:
                        return
                    req, cb, epoch = item
                    self._epochs[req.request_id] = epoch
                    try:
                        self.engine.submit(req, stream=cb)
                    except Exception as e:   # bad request, not a dead engine
                        self._post("err", self.idx, epoch, req.request_id, e)
                    try:
                        item = self.inbox.get_nowait()
                    except queue.Empty:
                        item = None
                if self.engine.scheduler.has_work():
                    for rec in self.engine.step():
                        self._post("done", self.idx,
                                   self._epochs.get(rec["rid"], 0),
                                   rec["rid"], rec)
        except BaseException as e:           # engine died: router re-dispatches
            self.error = e
            self._post("died", self.idx, 0, -1, e)

    # -- router-side load signal (racy reads of worker state are fine: these
    # are heuristics, and the GIL keeps each read itself consistent) --------

    @property
    def n_done(self) -> int:
        return int(self._m_done.value(replica=str(self.idx)))

    @property
    def n_tokens(self) -> int:
        return int(self._m_tokens.value(replica=str(self.idx)))

    @property
    def load(self) -> float:
        sched = self.engine.scheduler
        return self.inflight + (sched.n_reserved_pages
                                / max(self.engine.config.total_pages, 1))


class _Inflight:
    """Router-side record of one request across (re-)dispatches."""

    __slots__ = ("rid", "request", "future", "stream", "replica", "epoch",
                 "generated", "n_redispatched", "t_submit", "t_first")

    def __init__(self, rid: int, request: Request, future, stream):
        self.rid = rid
        self.request = request
        self.future = future
        self.stream = stream
        self.replica = -1
        self.epoch = 0
        self.generated: list[int] = []
        self.n_redispatched = 0
        self.t_submit = time.perf_counter()
        self.t_first: Optional[float] = None


class Router:
    """Load-balance streaming requests over N engine replicas.

    ``engines`` must be built from one ``EngineConfig`` (use
    ``Router.build``) — dispatch assumes replicas are interchangeable.
    Async surface: ``await start()``, ``fut = await submit(req)``,
    ``completion = await fut``, ``await stop()``. ``serve(requests)`` is
    the sync convenience wrapper mirroring ``ServeEngine.run``.
    """

    def __init__(self, engines: list[ServeEngine], *,
                 policy: str = "prefix", affinity_pages: int = 4,
                 max_inflight: Optional[int] = None,
                 stall_timeout_s: float = 30.0,
                 metrics=None):
        if not engines:
            raise ApiValidationError("router needs at least one replica")
        if policy not in ROUTE_POLICIES:
            raise ApiValidationError(
                f"unknown route policy {policy!r} — one of "
                f"{', '.join(ROUTE_POLICIES)}")
        self.policy = policy
        self.affinity_pages = int(affinity_pages)
        self.stall_timeout_s = float(stall_timeout_s)
        # router-level registry (each replica's engine has its own — see
        # ``to_prometheus`` for the merged fleet exposition)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "repro_router_requests_total", "requests submitted to the fleet")
        self._m_dispatches = self.metrics.counter(
            "repro_router_dispatches_total",
            "dispatches to a replica inbox (re-dispatches included)",
            labelnames=("replica",))
        self._m_backpressure = self.metrics.counter(
            "repro_router_backpressure_waits_total",
            "submit/dispatch waits for replica capacity")
        self._m_failovers = self.metrics.counter(
            "repro_router_failovers_total", "replicas marked failed")
        self._m_redispatches = self.metrics.counter(
            "repro_router_redispatches_total",
            "in-flight requests re-dispatched off a failed replica")
        m_done = self.metrics.counter(
            "repro_router_completions_total", "completions, by replica",
            labelnames=("replica",))
        m_tokens = self.metrics.counter(
            "repro_router_streamed_tokens_total",
            "tokens streamed to the router, by replica",
            labelnames=("replica",))
        self.replicas = [_Replica(i, e, m_done, m_tokens)
                         for i, e in enumerate(engines)]
        self.max_inflight = int(max_inflight
                                or 2 * engines[0].config.max_batch)
        self._inflight: dict[int, _Inflight] = {}
        self._completions: list[Completion] = []
        self._next_rid = 0
        self._rr = 0                       # round-robin counter
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._cap_event: Optional[asyncio.Event] = None
        self._monitor_task = None
        self._started = False
        self._fail_after = None            # (replica idx, n_tokens) hook

    @classmethod
    def build(cls, model, params, config: EngineConfig, n_replicas: int,
              **kw) -> "Router":
        """Spawn ``n_replicas`` identical engines from one ``EngineConfig``.
        All replicas share the same (compressed) ``params`` tree — jax
        arrays are immutable, so the weights exist once regardless of N."""
        engines = [ServeEngine(model, params, config)
                   for _ in range(int(n_replicas))]
        return cls(engines, **kw)

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        if self._started:
            return
        self._started = True
        self._loop = asyncio.get_running_loop()
        self._cap_event = asyncio.Event()

        def post(kind, idx, epoch, rid, payload):
            handler = {"done": self._on_done, "err": self._on_error,
                       "died": self._on_died}[kind]
            try:
                self._loop.call_soon_threadsafe(handler, idx, epoch, rid,
                                                payload)
            except RuntimeError:           # loop already closed (shutdown)
                pass

        for rep in self.replicas:
            rep._post = post
            if rep.failed:
                continue
            if rep.thread is None or not rep.thread.is_alive():
                # (re-)spawn the worker: the router is restartable — the
                # engines (and their warm compile + prefix caches) persist
                # across serve() waves, only the threads are per-run
                while not rep.inbox.empty():     # stale _STOPs from stop()
                    try:
                        rep.inbox.get_nowait()
                    except queue.Empty:
                        break
                rep.error = None
                rep.thread = threading.Thread(target=rep._run, daemon=True,
                                              name=f"replica-{rep.idx}")
                rep.thread.start()
        self._monitor_task = asyncio.ensure_future(self._monitor())

    async def stop(self) -> None:
        if not self._started:
            return
        if self._monitor_task is not None:
            self._monitor_task.cancel()
            self._monitor_task = None
        for rep in self.replicas:
            rep.inbox.put(_STOP)
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout=10.0)
        self._started = False

    async def _monitor(self):
        poll = min(0.05, self.stall_timeout_s / 4)
        while True:
            await asyncio.sleep(poll)
            for rep in self.replicas:
                if rep.failed:
                    continue
                dead = rep.error is not None or not rep.thread.is_alive()
                stalled = (rep.inflight > 0 and
                           time.monotonic() - rep.hb > self.stall_timeout_s)
                if dead or stalled:
                    self._handle_failure(
                        rep.idx, "died" if dead else
                        f"stalled (> {self.stall_timeout_s:g}s)")

    # -- dispatch -----------------------------------------------------------

    def _healthy(self) -> list[int]:
        return [r.idx for r in self.replicas if not r.failed]

    def _affinity_key(self, prompt: tuple) -> Optional[bytes]:
        """The leading page-aligned prompt tokens — the unit the radix
        prefix cache shares — as stable bytes; None when the prompt has no
        full page (nothing cacheable to be affine to)."""
        page = self.replicas[0].engine.config.page_size
        n_pages = min(len(prompt) // page, self.affinity_pages)
        if n_pages < 1:
            return None
        return np.asarray(prompt[:n_pages * page], np.int64).tobytes()

    def _rendezvous(self, key: bytes, candidates: list[int]) -> int:
        """Highest-random-weight hash: each replica scores the key; the
        max wins. Removing a replica remaps only *its* keys — the property
        that keeps warm prefix caches warm through membership churn."""
        def score(i: int) -> int:
            h = hashlib.blake2b(key + i.to_bytes(4, "little"),
                                digest_size=8).digest()
            return int.from_bytes(h, "little")
        return max(candidates, key=lambda i: (score(i), -i))

    def _least_loaded(self, candidates: list[int]) -> int:
        return min(candidates, key=lambda i: (self.replicas[i].load, i))

    def _choose(self, request: Request) -> Optional[int]:
        """Pick a replica with capacity, or None (caller awaits)."""
        healthy = self._healthy()
        if not healthy:
            raise ReplicaFailed("all replicas have failed")
        free = [i for i in healthy
                if self.replicas[i].inflight < self.max_inflight]
        if self.policy == "round-robin":
            i = healthy[self._rr % len(healthy)]
            self._rr += 1
            return i if self.replicas[i].inflight < self.max_inflight \
                else None
        if self.policy == "prefix":
            key = self._affinity_key(request.prompt)
            if key is not None:
                i = self._rendezvous(key, healthy)
                return i if self.replicas[i].inflight < self.max_inflight \
                    else None              # wait for the affine replica
        return self._least_loaded(free) if free else None

    async def submit(self, request: Request,
                     stream: Optional[Callable] = None) -> asyncio.Future:
        """Dispatch one ``api.Request``; returns a future resolving to its
        ``api.Completion``. ``stream(event: api.StreamEvent)`` fires in the
        event loop thread for every token (``event.replica`` names the
        serving replica; indices stay contiguous across a re-dispatch)."""
        if not self._started:
            await self.start()
        if not isinstance(request, Request):
            raise ApiValidationError(
                f"router.submit needs serve.api.Request, got "
                f"{type(request).__name__}")
        cfg = self.replicas[0].engine.config
        if request.sampling is not None and request.sampling != cfg.sampling:
            raise ApiValidationError(
                f"request.sampling={request.sampling} != the fleet's "
                f"compiled sampling={cfg.sampling} — replicas share one "
                "EngineConfig.sampling")
        if request.request_id is None:
            rid = self._next_rid
        else:
            rid = int(request.request_id)
            if rid in self._inflight:
                raise ApiValidationError(
                    f"request_id {rid} is already in flight")
        self._next_rid = max(self._next_rid, rid) + 1
        self._m_requests.inc()
        inf = _Inflight(rid, request, self._loop.create_future(), stream)
        self._inflight[rid] = inf
        await self._dispatch(inf)
        return inf.future

    async def _dispatch(self, inf: _Inflight) -> None:
        while True:
            try:
                idx = self._choose(inf.request)
            except ReplicaFailed as e:
                if not inf.future.done():
                    inf.future.set_exception(e)
                self._inflight.pop(inf.rid, None)
                return
            if idx is not None:
                break
            self._m_backpressure.inc()
            self._cap_event.clear()
            await self._cap_event.wait()   # backpressure: wait for capacity
        rep = self.replicas[idx]
        inf.replica = idx
        rep.inflight += 1
        done_already = len(inf.generated)
        req = inf.request
        if done_already:                   # resume after a replica failure:
            req = Request(                 # re-prefill prompt + generated
                prompt=req.prompt + tuple(inf.generated),
                max_new_tokens=req.max_new_tokens - done_already,
                eos_id=req.eos_id, priority=req.priority,
                sampling=req.sampling, request_id=inf.rid)
        elif req.request_id != inf.rid:
            req = Request(prompt=req.prompt,
                          max_new_tokens=req.max_new_tokens,
                          eos_id=req.eos_id, priority=req.priority,
                          sampling=req.sampling, request_id=inf.rid)
        epoch = inf.epoch

        def cb(ev: StreamEvent, _idx=idx, _epoch=epoch, _rid=inf.rid):
            # worker thread -> loop thread; stale epochs dropped there
            try:
                self._loop.call_soon_threadsafe(
                    self._on_token, _idx, _epoch, _rid, int(ev.token),
                    bool(ev.done))
            except RuntimeError:
                pass
        self._m_dispatches.inc(replica=str(idx))
        rep.inbox.put((req, cb, epoch))

    # -- event handlers (loop thread only) ----------------------------------

    def _live(self, idx: int, epoch: int, rid: int) -> Optional[_Inflight]:
        inf = self._inflight.get(rid)
        if inf is None or inf.epoch != epoch or inf.replica != idx:
            return None                    # stale: re-dispatched elsewhere
        return inf

    def _on_token(self, idx: int, epoch: int, rid: int, token: int,
                  done: bool) -> None:
        inf = self._live(idx, epoch, rid)
        if inf is None:
            return
        if inf.t_first is None:
            inf.t_first = time.perf_counter()
        index = len(inf.generated)
        inf.generated.append(token)
        rep = self.replicas[idx]
        rep._m_tokens.inc(replica=str(idx))
        if inf.stream is not None:
            inf.stream(StreamEvent(request_id=rid, token=token, index=index,
                                   done=done, replica=idx))
        if self._fail_after is not None and idx == self._fail_after[0] \
                and rep.n_tokens >= self._fail_after[1]:
            self._fail_after = None
            self._handle_failure(idx, "failure injected (fail_after)")

    def _on_done(self, idx: int, epoch: int, rid: int, rec: dict) -> None:
        inf = self._live(idx, epoch, rid)
        if inf is None:
            return
        self._finalize(inf, rec)

    def _on_error(self, idx: int, epoch: int, rid: int,
                  exc: BaseException) -> None:
        inf = self._live(idx, epoch, rid)
        if inf is None:
            return
        self.replicas[idx].inflight -= 1
        self._inflight.pop(rid, None)
        if not inf.future.done():
            inf.future.set_exception(exc)
        self._cap_event.set()

    def _on_died(self, idx: int, epoch: int, rid: int,
                 exc: BaseException) -> None:
        self._handle_failure(idx, f"worker raised {type(exc).__name__}: "
                                  f"{exc}")

    def _finalize(self, inf: _Inflight, rec: Optional[dict]) -> None:
        rep = self.replicas[inf.replica]
        rep.inflight -= 1
        rep._m_done.inc(replica=str(inf.replica))
        completion = Completion(
            request_id=inf.rid, tokens=tuple(inf.generated),
            n_prompt=len(inf.request.prompt), priority=inf.request.priority,
            n_cached=rec["n_cached"] if rec else 0,
            n_preempted=rec["n_preempted"] if rec else 0,
            n_redispatched=inf.n_redispatched, replica=inf.replica,
            t_submit=inf.t_submit, t_first=inf.t_first,
            t_done=time.perf_counter())
        self._inflight.pop(inf.rid, None)
        self._completions.append(completion)
        if not inf.future.done():
            inf.future.set_result(completion)
        self._cap_event.set()

    # -- failure handling ---------------------------------------------------

    def fail_replica(self, idx: int, reason: str = "failure injected",
                     ) -> None:
        """Force replica ``idx`` down (test/bench hook — the same path the
        monitor takes for a crashed or stalled worker)."""
        self._handle_failure(idx, reason)

    def fail_replica_after(self, idx: int, n_tokens: int) -> None:
        """Arm a deterministic failure: replica ``idx`` is killed as soon
        as it has streamed ``n_tokens`` tokens (router-side count)."""
        self._fail_after = (int(idx), int(n_tokens))

    def _handle_failure(self, idx: int, reason: str) -> None:
        rep = self.replicas[idx]
        if rep.failed:
            return
        rep.failed = True
        self._m_failovers.inc()
        rep.inbox.put(_STOP)
        victims = [inf for inf in self._inflight.values()
                   if inf.replica == idx]
        for inf in victims:
            inf.epoch += 1                 # drop stale events from the old
            inf.n_redispatched += 1        # dispatch (worker may still run)
            rep.inflight -= 1
            eos_hit = (inf.request.eos_id is not None and inf.generated
                       and inf.generated[-1] == inf.request.eos_id)
            if len(inf.generated) >= inf.request.max_new_tokens or eos_hit:
                # finished, but the done event raced the failure: finalize
                rep.inflight += 1          # _finalize decrements
                inf.n_redispatched -= 1
                self._finalize(inf, None)
                continue
            self._m_redispatches.inc()
            asyncio.ensure_future(self._dispatch(inf))
        self._cap_event.set()

    # -- fleet stats --------------------------------------------------------

    def fleet_stats(self, wall: Optional[float] = None,
                    completions: Optional[list] = None) -> dict:
        """Aggregate SLO stats — over ``completions`` when given (one
        serve() wave), else everything the router ever finished — plus
        per-replica counters (the per-replica ``prefix_hit_rate`` is what
        the affinity policy is buying)."""
        comps = (completions if completions is not None
                 else self._completions)

        def slo(cs) -> dict:
            return slo_summary(
                (c.ttft_s for c in cs if c.ttft_s is not None),
                (c.latency_s for c in cs), len(cs),
                n_preempted=sum(c.n_preempted for c in cs),
                n_redispatched=sum(c.n_redispatched for c in cs))

        n_new = sum(c.n_generated for c in comps)
        stats = {
            "n_replicas": len(self.replicas),
            "n_failed_replicas": sum(r.failed for r in self.replicas),
            "policy": self.policy,
            "n_generated": int(n_new),
            "n_prompt": int(sum(c.n_prompt for c in comps)),
            "n_cached_tokens": int(sum(c.n_cached for c in comps)),
            **slo(comps),
            "by_class": {c: slo([x for x in comps if x.priority == c])
                         for c in sorted({x.priority for x in comps})},
            "per_replica": [
                {"replica": r.idx, "failed": r.failed,
                 "n_requests": r.n_done, "n_generated": r.n_tokens,
                 "n_ticks": r.engine.n_ticks,
                 "n_preemptions": r.engine.scheduler.n_preemptions,
                 "prefix_hit_rate": (r.engine.prefix_cache.hit_rate
                                     if r.engine.prefix_cache is not None
                                     else 0.0)}
                for r in self.replicas],
        }
        if wall is not None:
            stats["wall_s"] = wall
            stats["tok_s"] = n_new / wall if wall > 0 else 0.0
        return stats

    def to_prometheus(self) -> str:
        """One exposition page for the whole fleet: the router's own
        registry plus every replica engine's registry, the latter tagged
        with a ``replica`` label."""
        parts = [self.metrics.to_prometheus()]
        parts += [r.engine.metrics.to_prometheus({"replica": r.idx})
                  for r in self.replicas]
        return "".join(parts)

    # -- sync convenience ---------------------------------------------------

    def serve(self, requests) -> dict:
        """Serve a batch to completion (sync wrapper): accepts the same
        request shapes as ``ServeEngine.run`` and returns the same
        ``{"results", "completions", "stats"}`` dict, with ``stats`` being
        ``fleet_stats``. Must not be called from inside an event loop."""
        return asyncio.run(self._serve(requests))

    async def _serve(self, requests) -> dict:
        reqs = []
        for r in requests:
            if isinstance(r, Request):
                reqs.append(r)
            elif isinstance(r, dict):
                reqs.append(Request(**r))
            else:
                prompt, gen = r
                reqs.append(Request(prompt=prompt, max_new_tokens=gen))
        await self.start()
        t0 = time.perf_counter()
        futs = [await self.submit(r) for r in reqs]
        completions = await asyncio.gather(*futs)
        wall = time.perf_counter() - t0
        await self.stop()
        return {"results": {c.request_id: list(c.tokens)
                            for c in completions},
                "completions": list(completions),
                "stats": self.fleet_stats(wall, list(completions))}
