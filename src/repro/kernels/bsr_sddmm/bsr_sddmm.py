"""Pallas TPU kernel: sampled dense-dense matmul (SDDMM) for BCSR weight
gradients.

Completes the paper's compressed-training kernel triad: forward
(dense x compressed'), backward-data (dense x compressed), and this —
backward-weights, computed ONLY at the surviving (nonzero) blocks:

    dW[block b at (r, c)] = dY[:, r-block]^T @ X[:, c-block]

During debias retraining (paper §2.4) the zero pattern is frozen, so a
dense (N, K) dW is pure waste at 90%+ sparsity: this kernel produces the
(n_slots, br, bc) block store directly — FLOPs and HBM bytes scale with
nnz blocks, not N*K. Grid: (n_slots, M/bm) with M innermost so each block's
accumulator stays VMEM-resident; per-slot (row, col) indices arrive via
scalar prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(rows_ref, cols_ref, dy_ref, x_ref, out_ref, *, n_m):
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0] += jax.lax.dot_general(
        dy_ref[...].astype(jnp.float32), x_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())),           # contract over the M dimension
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def sddmm_block_grad(dy, x, slot_rows, slot_cols, n_slots: int,
                     br: int, bc: int, *, bm: int = 128,
                     out_dtype=jnp.float32, interpret: bool = False):
    """dy: (M, N), x: (M, K); returns (n_slots, br, bc) block gradients.

    slot_rows/slot_cols: int32[n_slots] block coordinates per slot (slot 0
    is the BCSR pad slot; the wrapper zeroes its output).
    """
    m_dim = dy.shape[0]
    assert m_dim % bm == 0 and m_dim == x.shape[0]
    grid = (n_slots, m_dim // bm)

    def dy_map(s, m, rows, cols):
        return (m, rows[s])

    def x_map(s, m, rows, cols):
        return (m, cols[s])

    def out_map(s, m, rows, cols):
        return (s, 0, 0)

    return pl.pallas_call(
        functools.partial(_kernel, n_m=m_dim // bm),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, br), dy_map),
                pl.BlockSpec((bm, bc), x_map),
            ],
            out_specs=pl.BlockSpec((1, br, bc), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, br, bc), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(slot_rows, slot_cols, dy, x)
