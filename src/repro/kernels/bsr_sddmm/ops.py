"""Jit'd wrapper: masked BCSR weight gradient from a BlockCSR structure.

``bsr_weight_grad(x, dy, w)`` -> (n_slots, br, bc) gradient blocks aligned
with ``w.data`` (slot 0, the pad, is zero), i.e. a drop-in gradient for the
compressed weight store during mask-frozen (debias) retraining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bsr_sddmm.bsr_sddmm import sddmm_block_grad
from repro.kernels.bsr_sddmm import ref as ref_lib
from repro.kernels import use_interpret
from repro.obs.profile import kernel_call
from repro.sparse.formats import BlockCSR, PaletteBCSR


def _reject_palette(w):
    """Palette-quantized weights are a serving-only format: the SDDMM weight
    gradient targets fp block data, which a code/palette store doesn't have.
    Mask-frozen (debias) retraining must run on the BlockCSR form BEFORE
    quantization (``sparse.compress.quantize_compressed`` is the last
    pipeline stage; ``dequantize_compressed`` goes back if needed)."""
    if isinstance(w, PaletteBCSR):
        raise TypeError(
            "bsr_weight_grad got a PaletteBCSR: quantized weights are not "
            "trainable — debias before quantize_compressed(), or "
            "dequantize_compressed() to resume retraining")


def slot_coordinates(w: BlockCSR):
    """Per-slot (block-row, block-col, valid) vectors, derived jit-safely
    from the gather tables (slot 0 keeps (0, 0)).

    ``valid`` marks slots actually referenced by a gather entry: the pad
    slot 0 and any trailing slots added by ``formats.pad_bcsr`` (empty /
    fully-pruned layers padded up to a stacked slot count) are invalid and
    must carry zero gradient — without the mask they would silently pick up
    the (0, 0) block's gradient."""
    n_slots = w.data.shape[0]
    r_grid = w.gather_idx.shape[0]
    rows_src = jnp.repeat(jnp.arange(r_grid, dtype=jnp.int32),
                          w.gather_idx.shape[1])
    slots = w.gather_blk.reshape(-1)
    rows = jnp.zeros((n_slots,), jnp.int32).at[slots].set(rows_src)
    cols = jnp.zeros((n_slots,), jnp.int32).at[slots].set(
        w.gather_idx.reshape(-1).astype(jnp.int32))
    valid = jnp.zeros((n_slots,), bool).at[slots].set(True).at[0].set(False)
    return rows.at[0].set(0), cols.at[0].set(0), valid


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _bsr_weight_grad(x, dy, w: BlockCSR, *, bm: int = 128,
                     interpret: bool | None = None):
    interpret = use_interpret() if interpret is None else interpret
    br, bc = w.block
    m = x.shape[0]
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
    # pad feature dims to the block grid
    n_pad = w.block_grid[0] * br
    k_pad = w.block_grid[1] * bc
    if dy.shape[1] != n_pad:
        dy = jnp.pad(dy, ((0, 0), (0, n_pad - dy.shape[1])))
    if x.shape[1] != k_pad:
        x = jnp.pad(x, ((0, 0), (0, k_pad - x.shape[1])))
    rows, cols, valid = slot_coordinates(w)
    out = sddmm_block_grad(dy, x, rows, cols, w.data.shape[0], br, bc,
                           bm=bm, interpret=interpret)
    # pad slots (slot 0 + pad_bcsr padding) carry no gradient
    return out * valid[:, None, None].astype(out.dtype)


def bsr_weight_grad(x, dy, w: BlockCSR, *, bm: int = 128,
                    interpret: bool | None = None):
    """x: (M, K) activations; dy: (M, N) output cotangent; w: (N, K) BCSR.

    Returns (n_slots, br, bc) f32 gradient blocks for w.data."""
    _reject_palette(w)
    return kernel_call("bsr_sddmm/bsr_weight_grad", _bsr_weight_grad, x, dy,
                       w, bm=bm, interpret=interpret)


def bsr_weight_grad_ref(x, dy, w: BlockCSR):
    _reject_palette(w)
    rows, cols, valid = slot_coordinates(w)
    br, bc = w.block
    n_pad = w.block_grid[0] * br
    k_pad = w.block_grid[1] * bc
    dy = jnp.pad(dy, ((0, 0), (0, n_pad - dy.shape[1])))
    x = jnp.pad(x, ((0, 0), (0, k_pad - x.shape[1])))
    out = ref_lib.sddmm_block_grad_ref(dy, x, rows, cols,
                                       w.data.shape[0], br, bc)
    return out * valid[:, None, None].astype(out.dtype)
