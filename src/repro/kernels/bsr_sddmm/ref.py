"""Pure-jnp oracle for the SDDMM block-gradient kernel."""
from __future__ import annotations

import jax.numpy as jnp


def sddmm_block_grad_ref(dy, x, slot_rows, slot_cols, n_slots, br, bc):
    """Dense dW = dY^T X, then gather the blocks at the slot coordinates."""
    dw = dy.astype(jnp.float32).T @ x.astype(jnp.float32)   # (N, K)
    out = []
    for s in range(n_slots):
        r, c = int(slot_rows[s]), int(slot_cols[s])
        out.append(dw[r * br:(r + 1) * br, c * bc:(c + 1) * bc])
    return jnp.stack(out)
