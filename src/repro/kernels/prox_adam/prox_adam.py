"""Pallas TPU kernel: fused Prox-ADAM / Prox-RMSProp update.

TPU analogue of the paper's elementwise prox OpenCL kernel (Fig. 4), fused
with the full optimizer update. Unfused, one ADAM+prox step reads/writes each
of (w, g, m, v) several times through HBM; fused, each tensor crosses HBM
exactly once per direction — the update is purely memory-bound, so fusion is
worth ~4-7x on the optimizer step (see EXPERIMENTS.md §Perf napkin math).

Scalars (lr, lambda, t and the betas' running powers) arrive via scalar
prefetch in SMEM so one compiled kernel serves every step.

Layout: params are flattened and tiled to (rows, LANE)= (8k, 128)-aligned 2D
blocks by ops.py; the kernel itself is shape-agnostic over (bm, 128*q) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(sc_ref,                      # (8,) scalar prefetch
            w_ref, g_ref, m_ref, v_ref,  # inputs (VMEM)
            wo_ref, mo_ref, vo_ref,      # outputs (VMEM)
            *, rule: str, apply_prox: bool):
    lr = sc_ref[0]
    lam = sc_ref[1]
    b1 = sc_ref[2]
    b2 = sc_ref[3]
    eps = sc_ref[4]
    bc1 = sc_ref[5]   # 1 - b1**t  (bias-correction denominators, host side)
    bc2 = sc_ref[6]   # 1 - b2**t

    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...]

    if rule == "adam":
        m = m_ref[...]
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        d = mhat / (jnp.sqrt(vhat) + eps)
        mo_ref[...] = m2
    elif rule == "rmsprop":
        v2 = b2 * v + (1.0 - b2) * g * g
        d = g / (jnp.sqrt(v2) + eps)
        mo_ref[...] = m_ref[...]
    else:
        raise ValueError(rule)

    z = w - lr * d
    if apply_prox:
        tau = lr * lam
        # paper Fig. 4 min/max form of soft thresholding
        z = jnp.minimum(jnp.maximum(z - tau, 0.0), z + tau)
    wo_ref[...] = z.astype(wo_ref.dtype)
    vo_ref[...] = v2


def fused_prox_update(w, g, m, v, scalars, *, rule: str = "adam",
                      apply_prox: bool = True, bm: int = 256,
                      interpret: bool = False):
    """One fused optimizer+prox step over a 2D (rows, 128k)-shaped view.

    scalars: float32[8] = [lr, lam, b1, b2, eps, 1-b1^t, 1-b2^t, pad].
    Returns (w', m', v').
    """
    rows, cols = w.shape
    assert rows % bm == 0 and cols % 128 == 0, (w.shape, bm)
    grid = (rows // bm,)

    def tile(i, sc):
        return (i, 0)

    kern = functools.partial(_kernel, rule=rule, apply_prox=apply_prox)
    spec = pl.BlockSpec((bm, cols), tile)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec, spec],
            out_specs=[spec,
                       pl.BlockSpec((bm, cols), tile),
                       pl.BlockSpec((bm, cols), tile)],
        ),
        out_shape=[jax.ShapeDtypeStruct(w.shape, w.dtype),
                   jax.ShapeDtypeStruct(m.shape, jnp.float32),
                   jax.ShapeDtypeStruct(v.shape, jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(scalars, w, g, m, v)
    return out
