"""Pure-jnp oracle for the fused Prox-ADAM/Prox-RMSProp kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_prox_update_ref(w, g, m, v, scalars, *, rule="adam",
                          apply_prox=True):
    lr, lam, b1, b2, eps, bc1, bc2 = [scalars[i] for i in range(7)]
    g32 = g.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    if rule == "adam":
        m2 = b1 * m + (1.0 - b1) * g32
        v2 = b2 * v + (1.0 - b2) * g32 * g32
        d = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
    elif rule == "rmsprop":
        m2 = m
        v2 = b2 * v + (1.0 - b2) * g32 * g32
        d = g32 / (jnp.sqrt(v2) + eps)
    else:
        raise ValueError(rule)
    z = w32 - lr * d
    if apply_prox:
        tau = lr * lam
        z = jnp.minimum(jnp.maximum(z - tau, 0.0), z + tau)
    return z.astype(w.dtype), m2, v2
