"""Jit'd wrapper: fused Prox-ADAM over arbitrary param pytrees.

Each leaf is flattened, padded to a (bm, 128)-aligned 2D view, updated by the
fused kernel, and reshaped back. On TPU this is the production optimizer
path; on this CPU container it runs with interpret=True and is validated
against both ref.py and the pure-jnp optimizer in core/optimizers.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.prox import default_regularized_predicate
from repro.kernels.prox_adam.prox_adam import fused_prox_update
from repro.kernels import use_interpret
from repro.kernels.prox_adam import ref as ref_lib
from repro.obs.profile import kernel_call
_LANES = 128


def _to_tiles(x, bm):
    """Flatten to (rows, 128) with rows a multiple of bm; return view + meta."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = _LANES
    rows = -(-n // cols)
    rows = -(-rows // bm) * bm
    pad = rows * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, cols), n


def _from_tiles(t, n, shape, dtype):
    return t.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit,
                   static_argnames=("rule", "apply_prox", "bm", "interpret"))
def _fused_update_leaf(w, g, m, v, scalars, *, rule="adam", apply_prox=True,
                       bm=256, interpret=None):
    interpret = use_interpret() if interpret is None else interpret
    wt, n = _to_tiles(w, bm)
    gt, _ = _to_tiles(g.astype(jnp.float32), bm)
    mt, _ = _to_tiles(m, bm)
    vt, _ = _to_tiles(v, bm)
    wo, mo, vo = fused_prox_update(wt, gt, mt, vt, scalars, rule=rule,
                                   apply_prox=apply_prox, bm=bm,
                                   interpret=interpret)
    return (_from_tiles(wo, n, w.shape, w.dtype),
            _from_tiles(mo, n, m.shape, jnp.float32),
            _from_tiles(vo, n, v.shape, jnp.float32))


def fused_update_leaf(w, g, m, v, scalars, *, rule="adam", apply_prox=True,
                      bm=256, interpret=None):
    return kernel_call("prox_adam/fused_update_leaf", _fused_update_leaf,
                       w, g, m, v, scalars, rule=rule, apply_prox=apply_prox,
                       bm=bm, interpret=interpret)


def make_scalars(lr, lam, b1, b2, eps, t):
    """float32[8] scalar-prefetch vector; bias-correction terms precomputed."""
    t = jnp.asarray(t, jnp.float32)
    return jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(lam, jnp.float32),
                      jnp.asarray(b1, jnp.float32),
                      jnp.asarray(b2, jnp.float32),
                      jnp.asarray(eps, jnp.float32),
                      1.0 - jnp.power(jnp.asarray(b1, jnp.float32), t),
                      1.0 - jnp.power(jnp.asarray(b2, jnp.float32), t),
                      jnp.zeros((), jnp.float32)])


def fused_tree_update(params, grads, m, v, scalars, *, rule="adam",
                      predicate=None, interpret=None):
    """Whole-pytree fused update; non-regularized leaves skip the prox."""
    predicate = predicate or default_regularized_predicate
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    fg = treedef.flatten_up_to(grads)
    fm = treedef.flatten_up_to(m)
    fv = treedef.flatten_up_to(v)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, mm, vv in zip(flat, fg, fm, fv):
        name = jax.tree_util.keystr(path)
        w2, m2, v2 = fused_update_leaf(p, g, mm, vv, scalars, rule=rule,
                                       apply_prox=predicate(name, p),
                                       interpret=interpret)
        new_p.append(w2)
        new_m.append(m2)
        new_v.append(v2)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, new_p), unf(treedef, new_m), unf(treedef, new_v)


fused_prox_update_ref = ref_lib.fused_prox_update_ref
