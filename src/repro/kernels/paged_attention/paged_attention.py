"""Pallas TPU kernel: page-table gather fused with flash-decode attention.

The serving engine's mixed step (``models/attention.paged_attention``) is
the hot path of continuous batching, and its jnp reference gathers the
ENTIRE paged KV pool into a dense ``(B, P*page_size, kv, hd)`` context and
materializes a full score tensor every tick — O(max-context) HBM traffic
and FLOPs per decode token. This kernel is the EIE-style fix: the page
table rides into SMEM as a scalar-prefetch operand and each grid step DMAs
exactly ONE physical KV page into VMEM via the BlockSpec index map — the
gathered context never exists. Attention over the pages is the standard
online-softmax recurrence (running max / sum / accumulator in VMEM), with

* causal-by-absolute-position masking: query at absolute position q sees
  keys at absolute positions <= q (the mixed prefill/decode contract),
* optional sliding-window masking ((q_pos - k_pos) < window),
* page skipping: pages entirely above the causal frontier or entirely
  below the window floor are skipped with ``@pl.when`` (FLOPs saved on
  hardware; the trip count stays static so the Mosaic schedule does too),
* a flash-decode KV-split axis: the logical pages of a slot are cut into
  ``kv_splits`` segments processed by independent grid lanes, each
  emitting an UNNORMALIZED partial (acc, m, l); the cross-split softmax
  combine lives in ``ops.paged_flash_attention``. Decode ticks (one query
  row) have no query-axis parallelism to offer — splitting the KV axis is
  what keeps long-context decode from serializing on one core.

Grid: (B, KV, S, PP) with the page axis innermost ('arbitrary'); B, kv
head and split lanes are 'parallel'. Queries arrive pre-grouped as
(B, KV, C, g, hd) — the g query heads sharing a kv head are flattened into
the row axis of one (C*g, hd) x (hd, page_size) matmul per page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(table_ref, start_ref,            # scalar-prefetch (SMEM)
            q_ref, pos_ref, k_ref, v_ref,    # VMEM tiles
            acc_out, m_out, l_out,           # unnormalized partials
            m_sc, l_sc, acc_sc,              # VMEM carries across pages
            *, scale, window, ps, n_pages_per_split, n_logical_pages):
    b = pl.program_id(0)
    s = pl.program_id(2)
    j = pl.program_id(3)
    c, g, hd = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    page = s * n_pages_per_split + j
    start = start_ref[b]
    # page skip: logical page `page` covers absolute positions
    # [page*ps, page*ps + ps). Past the table, above the causal frontier
    # (first key position > last query position) or entirely below the
    # sliding-window floor -> contributes nothing, skip the matmuls.
    run = page < n_logical_pages
    run &= page * ps <= start + c - 1
    if window is not None:
        run &= page * ps + ps - 1 >= start - window + 1

    @pl.when(run)
    def _page():
        q = q_ref[0, 0].reshape(c * g, hd).astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (ps, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        q_pos = pos_ref[0].reshape(c, 1)                   # absolute q pos
        k_pos = page * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        mask = q_pos >= k_pos                              # (c, ps) causal
        if window is not None:
            mask &= (q_pos - k_pos) < window
        sc = jnp.where(mask[:, None, :], sc.reshape(c, g, ps),
                       NEG_INF).reshape(c * g, ps)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1))
        p = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = corr * l_sc[...] + jnp.sum(p, axis=1)
        acc_sc[...] = corr[:, None] * acc_sc[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == n_pages_per_split - 1)
    def _emit():
        # UNNORMALIZED partial per split lane: ops.paged_flash_attention
        # runs the cross-split combine. A lane whose pages were all skipped
        # emits (m=-inf, l=0, acc=0) and drops out of the combine.
        m_out[0, 0, 0] = m_sc[...].reshape(c, g)
        l_out[0, 0, 0] = l_sc[...].reshape(c, g)
        acc_out[0, 0, 0] = acc_sc[...].reshape(c, g, hd)


def paged_flash_fwd(q, k_pool, v_pool, page_table, positions, start, *,
                    window=None, kv_splits: int = 1,
                    interpret: bool = False):
    """Unnormalized flash-decode partials over a block-paged KV pool.

    q          : (B, KV, C, g, hd) queries grouped per kv head
    k/v_pool   : (n_pages, page_size, KV, hd) physical page pools
    page_table : (B, P) int32 — physical page of each slot's logical page
    positions  : (B, C) int32 absolute positions (= start[:, None] + arange)
    start      : (B,) int32 first absolute position of the tick

    Returns (acc, m, l): acc (B, KV, S, C, g, hd) f32 and m/l
    (B, KV, S, C, g) f32 — per-KV-split running max / sum / accumulator,
    to be combined by the caller (S = kv_splits).
    """
    b, kv, c, g, hd = q.shape
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    n_logical = page_table.shape[1]
    s_lanes = int(kv_splits)
    assert 1 <= s_lanes <= n_logical, (kv_splits, n_logical)
    pp = -(-n_logical // s_lanes)            # pages per split lane (ceil)
    scale = hd ** -0.5

    def q_map(bi, ki, si, ji, table_s, start_s):
        return (bi, ki, 0, 0, 0)

    def pos_map(bi, ki, si, ji, table_s, start_s):
        return (bi, 0)

    def kv_map(bi, ki, si, ji, table_s, start_s):
        # THE gather: the page axis of the pool is indexed through the
        # SMEM-prefetched page table, so only this slot's current page is
        # DMA'd. Lanes past the table end (si*pp + ji >= P) clamp to a
        # valid entry; the kernel's `run` predicate ignores their tile.
        page = jnp.minimum(si * pp + ji, n_logical - 1)
        return (table_s[bi, page], 0, ki, 0)

    def out_map(bi, ki, si, ji, table_s, start_s):
        return (bi, ki, si, 0, 0, 0)

    def ml_map(bi, ki, si, ji, table_s, start_s):
        return (bi, ki, si, 0, 0)

    kern = functools.partial(_kernel, scale=scale, window=window, ps=ps,
                             n_pages_per_split=pp,
                             n_logical_pages=n_logical)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kv, s_lanes, pp),
            in_specs=[
                pl.BlockSpec((1, 1, c, g, hd), q_map),
                pl.BlockSpec((1, c), pos_map),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, c, g, hd), out_map),
                pl.BlockSpec((1, 1, 1, c, g), ml_map),
                pl.BlockSpec((1, 1, 1, c, g), ml_map),
            ],
            scratch_shapes=[pltpu.VMEM((c * g,), jnp.float32),
                            pltpu.VMEM((c * g,), jnp.float32),
                            pltpu.VMEM((c * g, hd), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, s_lanes, c, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, s_lanes, c, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, s_lanes, c, g), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32),
      q, positions.astype(jnp.int32), k_pool, v_pool)
