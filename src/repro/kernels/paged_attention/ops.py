"""Jit'd wrapper: model-layout paged attention with the flash-decode
cross-split combine.

``paged_flash_attention`` is the serving engine's pallas-backend attention
(``models/attention.paged_attention`` dispatches here when the resolved
backend is 'pallas'): the kernel walks each slot's page table page by page
(the gathered ``(B, P*page_size, ...)`` context is never materialized) and
emits per-KV-split UNNORMALIZED partials (acc, m, l); this wrapper runs
the flash-decode combine

    m*   = max_s m_s
    out  = sum_s exp(m_s - m*) * acc_s  /  max(sum_s exp(m_s - m*) * l_s, eps)

which is exact — for ``kv_splits == 1`` it reduces to the ordinary
``acc / l`` normalization, so 1-split and N-split agree to float rounding
(tested). Empty split lanes (every page skipped) carry (m=-inf, l=0,
acc=0) and drop out of both sums.

Interpret mode resolves through ``kernels.use_interpret()`` (compiled on
TPU, interpret elsewhere, ``REPRO_PALLAS_INTERPRET`` overrides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.paged_attention import ref as ref_lib
from repro.kernels.paged_attention.paged_attention import paged_flash_fwd
from repro.obs.profile import kernel_call


@functools.partial(jax.jit,
                   static_argnames=("window", "kv_splits", "interpret"))
def _paged_flash_attention(q, k_pool, v_pool, page_table, positions, *,
                           window=None, kv_splits: int = 1, interpret=None):
    b, c, h, hd = q.shape
    kv = k_pool.shape[2]
    g = h // kv
    qg = q.reshape(b, c, kv, g, hd).transpose(0, 2, 1, 3, 4)
    start = positions[:, 0]
    if interpret is None:
        interpret = use_interpret()
    acc, m, l = paged_flash_fwd(
        qg.astype(jnp.float32), k_pool, v_pool, page_table, positions,
        start, window=window, kv_splits=kv_splits, interpret=interpret)
    # cross-split softmax combine (exact; identity at kv_splits == 1)
    m_star = jnp.max(m, axis=2)                            # (B, KV, C, g)
    w = jnp.exp(m - m_star[:, :, None])                    # (B, KV, S, C, g)
    l_tot = jnp.sum(w * l, axis=2)
    acc_tot = jnp.sum(w[..., None] * acc, axis=2)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]   # (B, KV, C, g, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, hd)


def paged_flash_attention(q, k_pool, v_pool, page_table, positions, *,
                          window=None, kv_splits: int = 1, interpret=None):
    """q: (B, C, H, hd); k/v_pool: (n_pages, ps, KV, hd);
    page_table: (B, P) int32; positions: (B, C) int32 ABSOLUTE positions —
    the engine contract ``positions = start_pos[:, None] + arange(C)``
    (the kernel's page-skip predicates assume row 0 is the tick start).

    Returns (B, C, H, hd) f32 attention output; invalid query rows carry
    finite garbage exactly like the ref path.
    """
    return kernel_call("paged_attention/paged_flash_attention",
                       _paged_flash_attention, q, k_pool, v_pool, page_table,
                       positions, window=window, kv_splits=kv_splits,
                       interpret=interpret)


paged_attention_ref = ref_lib.paged_attention_ref
