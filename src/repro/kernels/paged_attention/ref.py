"""Pure-jnp oracle for the paged-attention kernel.

The same math ``models/attention.paged_attention`` runs on the 'ref'
backend, as a standalone function over raw pools — gather the whole page
table into a dense context, mask by absolute position, softmax. Used by
the kernel parity tests and the kernel bench; deliberately materializes
the ``(B, P*page_size, kv, hd)`` context the kernel exists to avoid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, page_table, positions, *,
                        window: Optional[int] = None):
    """q: (B, C, H, hd); k/v_pool: (n_pages, ps, KV, hd);
    page_table: (B, P) int32; positions: (B, C) int32 absolute positions.

    Returns (B, C, H, hd) f32. Rows whose query is invalid (an inactive
    slot / past-``n_tokens`` tail) return finite garbage, same as the
    kernel path — callers discard them downstream.
    """
    b, c, h, hd = q.shape
    ps, kv = k_pool.shape[1], k_pool.shape[2]
    p_log = page_table.shape[1]
    g = h // kv
    k_ctx = k_pool[page_table].reshape(b, p_log * ps, kv, hd)
    v_ctx = v_pool[page_table].reshape(b, p_log * ps, kv, hd)

    qg = q.reshape(b, c, kv, g, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k_ctx,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    k_pos = jnp.arange(p_log * ps, dtype=jnp.int32)
    mask = k_pos[None, None, :] <= positions[:, :, None]        # (B, C, K)
    if window is not None:
        mask &= (positions[:, :, None] - k_pos[None, None, :]) < window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)

    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bkgqh", pattn, v_ctx.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, h, hd)
