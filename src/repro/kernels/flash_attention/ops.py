"""Jit'd wrapper: model-layout flash attention with jnp backward.

Forward runs the Pallas kernel (interpret mode on CPU; Mosaic on TPU); the
custom VJP recomputes attention with the streaming-jnp formulation for
backward (flash-style recompute — no stored probabilities). Model code
selects this backend via attention.ATTN_BACKEND = 'pallas' (TPU serving /
prefill path); the CPU dry-run keeps the jnp path so the artifact compiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels import use_interpret
from repro.kernels.flash_attention import ref as ref_lib


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk",
                                    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128,
                    interpret=None):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    interpret = use_interpret() if interpret is None else interpret
    b, s, h, hd = q.shape
    kv = k.shape[2]
    bq = min(bq, s)
    bk = min(bk, s)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    of = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    return of.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


flash_attention_ref = ref_lib.flash_attention_ref
