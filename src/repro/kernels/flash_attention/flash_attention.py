"""Pallas TPU flash-attention forward kernel (beyond-paper optimization).

Why it exists here: the dry-run roofline shows every attention architecture
memory-bound — the streaming-softmax in jnp keeps (q_chunk, kv_chunk) score
tiles crossing HBM ~4x per chunk pair, so attention-interior traffic scales
with S^2. This kernel keeps the score tile, running max/sum, and the output
accumulator in VMEM: HBM traffic collapses to the q/k/v/o kernel I/O (2/S of
the interior traffic; EXPERIMENTS.md §Perf iteration A3/K1).

Schedule: grid (B*H, nq, nkv), kv innermost; VMEM scratch carries
(m, l, acc) across kv blocks; causal block-skip via @pl.when (also halves
the FLOPs vs the masked-dense jnp path). GQA is handled in the k/v
BlockSpec index maps (kv head = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref,
            m_sc, l_sc, acc_sc,
            *, scale, causal, window, bq, bk, nkv):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # causal block skip: kv block strictly above the diagonal contributes
    # nothing -> skip its matmuls entirely (FLOPs saved on real hardware)
    run = jnp.bool_(True)
    if causal:
        run = (j * bk) <= (i * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = corr * l_sc[...] + jnp.sum(p, axis=1)
        acc_sc[...] = corr[:, None] * acc_sc[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=None,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """q: (BH, S, hd); k, v: (BKV, S, hd) with BH = BKV * group.

    Returns o: (BH, S, hd)."""
    bh, s, hd = q.shape
    bkv = k.shape[0]
    g = bh // bkv
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nkv = s // bq, s // bk
    scale = hd ** -0.5

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, nkv=nkv)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
