"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (BH, S, hd); k, v: (BKV, S, hd). Naive masked softmax attention."""
    bh, s, hd = q.shape
    g = bh // k.shape[0]
    kk = jnp.repeat(k, g, axis=0)
    vv = jnp.repeat(v, g, axis=0)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
