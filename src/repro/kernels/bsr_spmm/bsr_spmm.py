"""Pallas TPU kernel: gather-block-matmul for BlockCSR weights.

TPU adaptation of the paper's two OpenCL kernels (Figs. 2-3):

  forward : Y  = X  @ W'   (dense x compressed')   W is (N, K) BCSR
  backward: dX = dY @ W    (dense x compressed)

Both reduce to one *gather-matmul-accumulate* schedule: for each output tile
(i, o) accumulate ``D_tile(i, idx[o, j]) @ B(blk[o, j])`` over the nonzero
blocks j of output block-row o. The paper's coalesced-thread-access argument
maps onto scalar-prefetched BlockSpec index maps: the sparsity pattern lives
in SMEM-prefetched int32 tables, so the DMA engine fetches exactly the
nonzero (MXU-aligned) blocks from HBM into VMEM — contiguity by construction
rather than by thread scheduling.

The forward pass consumes the block-CSR gather tables; the backward consumes
the block-CSC (transposed) tables precomputed on host, avoiding the
uncoalesced column walk the paper accepts in its Fig. 3 kernel.

``gather_block_matmul_palette`` is the quantized-serving variant (Deep
Compression stage 2): the block store holds uint8 palette codes (nibble-
packed at 4 bits) and the per-matrix fp32 palette rides into VMEM as one
extra (1, 2**bits) operand. Dequantization is fused into the accumulate:
codes are expanded via a one-hot x palette matvec (MXU-friendly; TPU Mosaic
has no vector gather), so HBM traffic per block drops 4x/8x while the
matmul itself is unchanged.

Grid: (M/bm, O/bo, Jmax), J innermost so the output tile stays resident in
VMEM across the accumulation. Padded gather slots point at data slot 0 (an
all-zero block), so accumulating them is a no-op and the kernel needs no
dynamic trip count — branchless, which keeps the Mosaic schedule static.
A ``@pl.when(j < nnz[o])`` guard is still used to skip the matmul FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.sparse.formats import unpack_uint4

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(nnz_ref, idx_ref, blk_ref,     # scalar-prefetch (SMEM)
            d_ref, w_ref, o_ref,            # VMEM tiles
            *, transpose_block: bool, out_dtype):
    j = pl.program_id(2)
    o = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j < nnz_ref[o])
    def _acc():
        d = d_ref[...]
        w = w_ref[0]                         # (br, bc) block
        if transpose_block:
            w = w.T
        o_ref[...] += jax.lax.dot(
            d.astype(jnp.float32), w.astype(jnp.float32),
            preferred_element_type=jnp.float32).astype(out_dtype)


def _palette_kernel(nnz_ref, idx_ref, blk_ref,   # scalar-prefetch (SMEM)
                    d_ref, c_ref, p_ref, o_ref,   # VMEM tiles
                    *, transpose_block: bool, bits: int, out_dtype):
    j = pl.program_id(2)
    o = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j < nnz_ref[o])
    def _acc():
        d = d_ref[...]
        codes = c_ref[0]                     # (br, bc) or (br, bc//2) uint8
        if bits == 4:
            codes = unpack_uint4(codes)      # pure jnp — one shared copy of
                                             # the nibble-ordering convention
        # fused dequant: one-hot(codes) @ palette — a (br*bc, P) x (P,)
        # matvec instead of a vector gather (which Mosaic lacks); code 0 hits
        # palette[0] == 0 so intra-block zeros and the pad slot stay exact
        palette = p_ref[0].astype(jnp.float32)      # (P,)
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), palette.shape[0],
                                dtype=jnp.float32)  # (br, bc, P)
        w = jax.lax.dot_general(onehot, palette,
                                (((2,), (0,)), ((), ())))
        if transpose_block:
            w = w.T
        o_ref[...] += jax.lax.dot(
            d.astype(jnp.float32), w,
            preferred_element_type=jnp.float32).astype(out_dtype)


def gather_block_matmul_palette(dense, codes, palette, idx, blk, nnz, *,
                                out_cols: int,
                                transpose_block: bool,
                                bits: int,
                                bm: int = 128,
                                out_dtype=jnp.float32,
                                interpret: bool = False):
    """Palette-quantized ``gather_block_matmul``: same schedule, the block
    store holds uint8 codes and the fp32 palette is dequantized in-kernel.

    codes   : (n_slots, br, bc) uint8 at bits=8, (n_slots, br, bc//2) at
              bits=4 (two nibble codes per byte, low nibble first)
    palette : (P,) fp32 with palette[0] == 0 (P = 2**bits)
    """
    M, Kin = dense.shape
    n_slots, br, bcs = codes.shape
    bc = bcs * 2 if bits == 4 else bcs
    O, jmax = idx.shape
    b_in, b_out = (bc, br) if transpose_block else (br, bc)
    assert Kin % b_in == 0 and out_cols % b_out == 0 and M % bm == 0, (
        dense.shape, codes.shape, out_cols, bm)
    assert out_cols // b_out == O

    pal2d = palette.reshape(1, -1)
    grid = (M // bm, O, jmax)

    def d_map(i, o, j, nnz_s, idx_s, blk_s):
        return (i, idx_s[o, j])

    def c_map(i, o, j, nnz_s, idx_s, blk_s):
        return (blk_s[o, j], 0, 0)

    def p_map(i, o, j, nnz_s, idx_s, blk_s):
        return (0, 0)

    def o_map(i, o, j, nnz_s, idx_s, blk_s):
        return (i, o)

    kernel = functools.partial(_palette_kernel,
                               transpose_block=transpose_block,
                               bits=bits, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, b_in), d_map),
                pl.BlockSpec((1, br, bcs), c_map),
                pl.BlockSpec((1, pal2d.shape[1]), p_map),
            ],
            out_specs=pl.BlockSpec((bm, b_out), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((M, out_cols), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nnz, idx, blk, dense, codes, pal2d)


def gather_block_matmul(dense, data, idx, blk, nnz, *,
                        out_cols: int,
                        transpose_block: bool,
                        bm: int = 128,
                        out_dtype=jnp.float32,
                        interpret: bool = False):
    """Y[m, o-block] = sum_j dense[m, idx[o,j]-block] @ B(blk[o,j]).

    dense : (M, Kin)  with Kin divisible by the block's inner dim
    data  : (n_slots, br, bc) BCSR block store (slot 0 = zero pad)
    idx   : (O, Jmax) int32 input-block-column table
    blk   : (O, Jmax) int32 data-slot table
    nnz   : (O,) int32 valid prefix per output block-row
    transpose_block: True for the forward X @ W' (blocks are (bo, bin) and
        need transposing); False for backward dY @ W (blocks are (bin, bo)).
    """
    M, Kin = dense.shape
    n_slots, br, bc = data.shape
    O, jmax = idx.shape
    b_in, b_out = (bc, br) if transpose_block else (br, bc)
    assert Kin % b_in == 0 and out_cols % b_out == 0 and M % bm == 0, (
        dense.shape, data.shape, out_cols, bm)
    assert out_cols // b_out == O

    grid = (M // bm, O, jmax)

    def d_map(i, o, j, nnz_s, idx_s, blk_s):
        return (i, idx_s[o, j])

    def w_map(i, o, j, nnz_s, idx_s, blk_s):
        return (blk_s[o, j], 0, 0)

    def o_map(i, o, j, nnz_s, idx_s, blk_s):
        return (i, o)

    kernel = functools.partial(_kernel, transpose_block=transpose_block,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, b_in), d_map),
                pl.BlockSpec((1, br, bc), w_map),
            ],
            out_specs=pl.BlockSpec((bm, b_out), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((M, out_cols), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nnz, idx, blk, dense, data)
