"""Jit'd public wrappers for the BCSR spmm kernel, with custom VJP.

``spmm(x, w)`` computes x @ w.T for a BlockCSR ``w`` (the paper's forward
dense x compressed'); its VJP reuses the same kernel with the transposed
gather tables (dense x compressed) for dx, and densifies only for dw (dw is
produced for the *training* path where w is still dense; the BCSR path is
the serving path, so dw is rarely needed — see models/layers.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm.bsr_spmm import (gather_block_matmul,
                                             gather_block_matmul_palette)
from repro.kernels.bsr_spmm import ref as ref_lib
from repro.kernels import use_interpret
from repro.obs.profile import kernel_call
from repro.sparse.formats import BlockCSR, PaletteBCSR


def _pad_rows(x, bm):
    m = x.shape[0]
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _spmm(x, w: BlockCSR, *, bm: int = 128, interpret: bool | None = None):
    interpret = use_interpret() if interpret is None else interpret
    n, k = w.shape
    xp, m = _pad_rows(x, bm)
    k_pad = w.block_grid[1] * w.block[1]
    if k_pad != xp.shape[1]:
        xp = jnp.pad(xp, ((0, 0), (0, k_pad - xp.shape[1])))
    y = gather_block_matmul(xp, w.data, w.gather_idx, w.gather_blk,
                            w.gather_nnz, out_cols=w.block_grid[0] * w.block[0],
                            transpose_block=True, bm=bm, interpret=interpret)
    return y[:m, :n]


def spmm(x, w: BlockCSR, *, bm: int = 128, interpret: bool | None = None):
    """Y (M, N) = X (M, K) @ W' for W (N, K) BlockCSR."""
    return kernel_call("bsr_spmm/spmm", _spmm, x, w, bm=bm,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _spmm_t(dy, w: BlockCSR, *, bm: int = 128, interpret: bool | None = None):
    interpret = use_interpret() if interpret is None else interpret
    n, k = w.shape
    dyp, m = _pad_rows(dy, bm)
    # pad N up to the block grid (gather tables index padded block rows)
    br, bc = w.block
    n_pad = w.block_grid[0] * br
    if n_pad != dyp.shape[1]:
        dyp = jnp.pad(dyp, ((0, 0), (0, n_pad - dyp.shape[1])))
    dx = gather_block_matmul(dyp, w.data, w.gather_t_idx, w.gather_t_blk,
                             w.gather_t_nnz, out_cols=w.block_grid[1] * bc,
                             transpose_block=False, bm=bm, interpret=interpret)
    return dx[:m, :k]


def spmm_t(dy, w: BlockCSR, *, bm: int = 128, interpret: bool | None = None):
    """dX (M, K) = dY (M, N) @ W for W (N, K) BlockCSR (backward)."""
    return kernel_call("bsr_spmm/spmm_t", _spmm_t, dy, w, bm=bm,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _spmm_palette(x, w: PaletteBCSR, *, bm: int = 128,
                  interpret: bool | None = None):
    interpret = use_interpret() if interpret is None else interpret
    n, k = w.shape
    xp, m = _pad_rows(x, bm)
    k_pad = w.block_grid[1] * w.block[1]
    if k_pad != xp.shape[1]:
        xp = jnp.pad(xp, ((0, 0), (0, k_pad - xp.shape[1])))
    y = gather_block_matmul_palette(
        xp, w.codes, w.palette, w.gather_idx, w.gather_blk, w.gather_nnz,
        out_cols=w.block_grid[0] * w.block[0], transpose_block=True,
        bits=w.bits, bm=bm, interpret=interpret)
    return y[:m, :n]


def spmm_palette(x, w: PaletteBCSR, *, bm: int = 128,
                 interpret: bool | None = None):
    """Y (M, N) = X (M, K) @ W' for W (N, K) PaletteBCSR — the quantized
    serving forward. Dequantization (palette lookup, nibble unpack at 4-bit)
    is fused into the gather-block-matmul kernel."""
    return kernel_call("bsr_spmm/spmm_palette", _spmm_palette, x, w, bm=bm,
                       interpret=interpret)


@jax.custom_vjp
def spmm_ad(x, w: BlockCSR):
    """Differentiable-in-x spmm (w is a constant serving-time structure)."""
    return spmm(x, w)


def _fwd(x, w):
    return spmm(x, w), w


def _bwd(w, dy):
    return spmm_t(dy, w), None


spmm_ad.defvjp(_fwd, _bwd)

# re-exported oracles for tests/benches
spmm_fwd_ref = ref_lib.spmm_fwd_ref
spmm_bwd_ref = ref_lib.spmm_bwd_ref
spmm_palette_fwd_ref = ref_lib.spmm_palette_fwd_ref
