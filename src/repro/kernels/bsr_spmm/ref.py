"""Pure-jnp oracle for the BCSR gather-block-matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparse.formats import BlockCSR, bcsr_to_dense


def spmm_fwd_ref(x, w: BlockCSR):
    """Y = X @ W' with W (N, K) sparse — paper's dense x compressed'."""
    wd = bcsr_to_dense(w)[: w.shape[0], : w.shape[1]]
    return x.astype(jnp.float32) @ wd.astype(jnp.float32).T


def spmm_bwd_ref(dy, w: BlockCSR):
    """dX = dY @ W — paper's dense x compressed."""
    wd = bcsr_to_dense(w)[: w.shape[0], : w.shape[1]]
    return dy.astype(jnp.float32) @ wd.astype(jnp.float32)


def spmm_palette_fwd_ref(x, w):
    """Quantized forward oracle: dequantize the palette codes to a BlockCSR
    then run the fp reference — Y = X @ dequant(W)'. ``w`` is a
    ``formats.PaletteBCSR``."""
    return spmm_fwd_ref(x, w.dequantize())


def gather_block_matmul_ref(dense, data, idx, blk, nnz, *, out_cols,
                            transpose_block):
    """Direct oracle of the gather-matmul-accumulate schedule itself."""
    n_slots, br, bc = data.shape
    O, jmax = idx.shape
    b_in, b_out = (bc, br) if transpose_block else (br, bc)
    M = dense.shape[0]
    out = jnp.zeros((M, out_cols), jnp.float32)
    d32 = dense.astype(jnp.float32)
    for o in range(O):
        acc = jnp.zeros((M, b_out), jnp.float32)
        for j in range(jmax):
            w = data[blk[o, j]].astype(jnp.float32)
            if transpose_block:
                w = w.T
            contrib = d32[:, idx[o, j] * b_in:(idx[o, j] + 1) * b_in] @ w
            acc = acc + jnp.where(j < nnz[o], 1.0, 0.0) * contrib
        out = out.at[:, o * b_out:(o + 1) * b_out].set(acc)
    return out
