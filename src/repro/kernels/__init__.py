# Pallas TPU kernels for the paper's compute hot spots:
#   bsr_spmm        - dense x BlockCSR gather-block-matmul (paper Figs. 2-3)
#   bsr_sddmm       - masked weight gradient at resident BCSR slots
#   flash_attention - online-softmax attention forward
#   paged_attention - page-table gather fused with flash-decode attention
#   prox_adam       - fused optimizer + soft-threshold update (paper Fig. 4)
from __future__ import annotations

import os

import jax

_FALSY = ("0", "false", "no", "off")


def use_interpret() -> bool:
    """Single point of truth for Pallas interpret-mode selection.

    Every ``kernels/*/ops.py`` wrapper resolves ``interpret=None`` through
    here: compiled (Mosaic) on TPU, interpret mode everywhere else, so
    flipping the whole kernel suite to compiled is the backend switch — not
    five per-package edits. ``REPRO_PALLAS_INTERPRET=1`` forces interpret
    mode on TPU (kernel debugging); ``REPRO_PALLAS_INTERPRET=0`` asserts
    compiled mode. Resolution happens at trace time: the jitted wrappers
    keep ``interpret=None`` as their static cache key, so set the env var
    before the first kernel call in a process.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env:
        return env not in _FALSY
    return jax.default_backend() != "tpu"
