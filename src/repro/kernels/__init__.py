# Pallas TPU kernels for the paper's compute hot spots:
#   bsr_spmm  - dense x BlockCSR gather-block-matmul (paper Figs. 2-3)
#   prox_adam - fused optimizer + soft-threshold update (paper Fig. 4)
