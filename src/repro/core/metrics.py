"""Compression-rate reporting (paper's compression tables, A1-A4).

compression_rate = #zeros / #total over regularized leaves; the 'x' factor in
the paper's tables is total/nnz.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.prox import default_regularized_predicate

PyTree = Any


def layer_compression(params: PyTree,
                      predicate: Optional[Callable] = None) -> dict[str, dict]:
    """Per-layer nnz/total table, mirroring paper Tables A1-A4."""
    predicate = predicate or default_regularized_predicate
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    table: dict[str, dict] = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if not predicate(name, leaf):
            continue
        nnz = int(jnp.sum(leaf != 0))
        total = int(leaf.size)
        table[name] = {
            "nnz": nnz,
            "total": total,
            "compression_rate": 1.0 - nnz / total,
            "x_factor": (total / nnz) if nnz else float("inf"),
        }
    return table


def total_compression(params: PyTree,
                      predicate: Optional[Callable] = None) -> dict:
    table = layer_compression(params, predicate)
    nnz = sum(v["nnz"] for v in table.values())
    total = sum(v["total"] for v in table.values())
    return {
        "nnz": nnz,
        "total": total,
        "compression_rate": 1.0 - nnz / max(total, 1),
        "x_factor": (total / nnz) if nnz else float("inf"),
    }


def compression_rate(params: PyTree,
                     predicate: Optional[Callable] = None) -> float:
    return total_compression(params, predicate)["compression_rate"]


def format_table(table: dict[str, dict], title: str = "") -> str:
    lines = []
    if title:
        lines.append(title)
    lines.append(f"{'layer':48s} {'nnz/total':>24s} {'rate':>8s} {'x':>8s}")
    for k, v in table.items():
        x = v["x_factor"]
        xs = f"{x:.0f}x" if x != float("inf") else "inf"
        lines.append(f"{k:48s} {v['nnz']:>11d}/{v['total']:<12d} "
                     f"{100*v['compression_rate']:7.2f}% {xs:>8s}")
    return "\n".join(lines)


def model_size_bytes(params: PyTree, sparse: bool = False,
                     index_bytes: int = 4) -> int:
    """Dense vs CSR-compressed model size (paper Table 3 'Model Size').

    Sparse size follows the CSR accounting: nnz * (value + column index)
    + rows * row-pointer, per regularized 2D leaf; non-regularized leaves
    stay dense.
    """
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        itemsize = leaf.dtype.itemsize
        if sparse and default_regularized_predicate(name, leaf):
            nnz = int(jnp.sum(leaf != 0))
            rows = leaf.shape[0] if leaf.ndim >= 1 else 1
            total += nnz * (itemsize + index_bytes) + (rows + 1) * index_bytes
        else:
            total += leaf.size * itemsize
    return total
