"""Pru baseline: magnitude pruning + retraining (Han et al. 2015, paper §4).

Pipeline (as the paper evaluates it):
  1. train the dense reference model,
  2. threshold: zero every regularized weight with |w| below a per-layer
     threshold chosen from a quality parameter q (threshold = q * std(w),
     Han et al.'s rule) OR from a target global sparsity,
  3. optional retraining with the zero mask frozen (Pru(Retrain)).

Step 3 reuses the debias machinery (core/masks.py + optimizer mask arg), so
Pru and SpC(Retrain) share one code path — mirroring the paper's observation
that retraining is the same operation in both methods.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.prox import default_regularized_predicate, hard_threshold

PyTree = Any


def magnitude_prune_std(params: PyTree, quality: float,
                        predicate: Optional[Callable] = None) -> PyTree:
    """Han et al. rule: per-layer threshold = quality * std(layer)."""
    predicate = predicate or default_regularized_predicate
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if predicate(name, leaf):
            tau = quality * jnp.std(leaf.astype(jnp.float32))
            out.append(hard_threshold(leaf, tau.astype(leaf.dtype)))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def magnitude_prune_global(params: PyTree, sparsity: float,
                           predicate: Optional[Callable] = None) -> PyTree:
    """Zero the smallest-|w| fraction ``sparsity`` across all regularized leaves."""
    predicate = predicate or default_regularized_predicate
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    mags = [jnp.abs(leaf.astype(jnp.float32)).ravel()
            for path, leaf in flat
            if predicate(jax.tree_util.keystr(path), leaf)]
    if not mags:
        return params
    allmag = jnp.concatenate(mags)
    k = jnp.clip(jnp.asarray(sparsity * allmag.size, jnp.int32), 0, allmag.size - 1)
    tau = jnp.sort(allmag)[k]
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append(hard_threshold(leaf, tau.astype(leaf.dtype))
                   if predicate(name, leaf) else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
