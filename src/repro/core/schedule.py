"""Learning-rate and lambda schedules.

The paper uses constant lr/lambda; we add warmup-cosine lr and a lambda ramp
(0 -> lambda over warmup steps) which stabilizes very high compression runs —
an ablation recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def lambda_ramp(lam: float, ramp_steps: int):
    """0 -> lam linearly over ramp_steps, then constant (beyond-paper)."""
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(ramp_steps, 1), 0.0, 1.0)
        return jnp.asarray(lam, jnp.float32) * frac
    return sched


def step_decay(value: float, decay: float, every: int):
    """value * decay^(step // every) — used by the MM baseline's mu ramp."""
    def sched(step):
        k = (step // every).astype(jnp.float32)
        return jnp.asarray(value, jnp.float32) * jnp.power(decay, k)
    return sched
