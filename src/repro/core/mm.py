"""MM baseline: learning-compression via the method of multipliers
(Carreira-Perpinan & Idelbayev 2018; paper §4.4, Eq. (3)-(4)).

The constrained problem  min L(w) + alpha*Psi(theta)  s.t. w = theta  is
solved on the augmented Lagrangian

    L(w) + (mu/2)||w - theta||^2 - lam^T (w - theta) + alpha*Psi(theta)

by alternating:
  (L-step)  several SGD steps on w of L(w) + (mu/2)||w - theta - lam/mu||^2,
  (C-step)  theta <- prox_{(alpha/mu)*Psi}(w - lam/mu)   (closed form),
  (M-step)  lam <- lam - mu (w - theta),    mu <- mu * mu_growth every T steps.

Memory: (w, grad, theta, lam) — ~2x the prox method's (w, grad) + (m, v),
which is the paper's Table 2 memory argument; we surface the state size in
``mm_state_bytes`` so benchmarks can report it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.prox import default_regularized_predicate, soft_threshold

PyTree = Any


class MMState(NamedTuple):
    step: jax.Array
    theta: PyTree        # auxiliary (compressed) copy of the params
    lam: PyTree          # Lagrange multipliers
    mu: jax.Array        # penalty parameter (ramped to infinity)
    momentum: PyTree     # SGD momentum buffer for the L-step


@dataclasses.dataclass(frozen=True)
class MMConfig:
    alpha: float = 1e-3          # regularization strength on theta
    mu0: float = 9.76e-5         # paper Table 2 (Lenet-5 setting)
    mu_growth: float = 1.1
    mu_every: int = 4000         # growth cadence (paper: x1.1 per 4k iters)
    c_step_every: int = 4000     # compression cadence (paper Fig. 8)
    learning_rate: float = 1e-2
    sgd_momentum: float = 0.9


def mm_init(params: PyTree, cfg: MMConfig) -> MMState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return MMState(step=jnp.zeros((), jnp.int32),
                   theta=jax.tree.map(lambda p: p.astype(jnp.float32), params),
                   lam=zeros,
                   mu=jnp.asarray(cfg.mu0, jnp.float32),
                   momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                         params))


def mm_update(grads: PyTree, state: MMState, params: PyTree, cfg: MMConfig,
              predicate: Optional[Callable] = None) -> tuple[PyTree, MMState]:
    """One MM iteration = one L-step SGD update (+ periodic C/M steps)."""
    predicate = predicate or default_regularized_predicate
    t = state.step + 1
    mu = state.mu

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_th = treedef.flatten_up_to(state.theta)
    flat_lm = treedef.flatten_up_to(state.lam)
    flat_mo = treedef.flatten_up_to(state.momentum)

    do_c = (t % cfg.c_step_every) == 0
    do_mu = (t % cfg.mu_every) == 0

    new_p, new_th, new_lm, new_mo = [], [], [], []
    for (path, p), g, th, lm, mo in zip(flat_p, flat_g, flat_th, flat_lm, flat_mo):
        name = jax.tree_util.keystr(path)
        p32, g32 = p.astype(jnp.float32), g.astype(jnp.float32)
        if predicate(name, p):
            # L-step gradient of L(w) + (mu/2)||w - theta - lam/mu||^2
            g_aug = g32 + mu * (p32 - th) - lm
        else:
            g_aug = g32
        mo2 = cfg.sgd_momentum * mo + g_aug
        w2 = p32 - cfg.learning_rate * mo2

        if predicate(name, p):
            # C-step: theta <- prox_{(alpha/mu) l1}(w - lam/mu)
            th_c = soft_threshold(w2 - lm / mu, cfg.alpha / mu)
            th2 = jnp.where(do_c, th_c, th)
            # M-step (same cadence as C-step)
            lm2 = jnp.where(do_c, lm - mu * (w2 - th2), lm)
        else:
            th2, lm2 = th, lm

        new_p.append(w2.astype(p.dtype))
        new_th.append(th2)
        new_lm.append(lm2)
        new_mo.append(mo2)

    mu2 = jnp.where(do_mu, mu * cfg.mu_growth, mu)
    unf = jax.tree_util.tree_unflatten
    return (unf(treedef, new_p),
            MMState(step=t, theta=unf(treedef, new_th), lam=unf(treedef, new_lm),
                    mu=mu2, momentum=unf(treedef, new_mo)))


def mm_final_params(params: PyTree, state: MMState,
                    predicate: Optional[Callable] = None) -> PyTree:
    """At convergence MM returns theta (the compressed copy) for regularized
    leaves and w elsewhere."""
    predicate = predicate or default_regularized_predicate
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_th = treedef.flatten_up_to(state.theta)
    out = [th.astype(p.dtype) if predicate(jax.tree_util.keystr(path), p) else p
           for (path, p), th in zip(flat_p, flat_th)]
    return jax.tree_util.tree_unflatten(treedef, out)


def mm_state_bytes(state: MMState) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
