# The paper's primary contribution: l1 sparse coding with proximal optimizers
# (Prox-RMSProp / Prox-ADAM), debiasing, and the Pru / MM baselines.
from repro.core import (masks, metrics, mm, optimizers, prox, pruning,  # noqa: F401
                        quantize, schedule)
from repro.core.optimizers import (get_optimizer, prox_adam, prox_rmsprop,  # noqa: F401
                                   prox_sgd)
from repro.core.prox import soft_threshold, tree_prox  # noqa: F401
