"""Weight-sharing quantization + Huffman size accounting (beyond-paper).

The paper positions Deep Compression (Han et al. 2016, its ref. [24]) as the
follow-up to pruning: after sparsification, surviving weights are k-means
clustered to a small palette ("trained quantization") and the indices
Huffman-coded. We add that stage on top of SpC so the full
prune → quantize → encode pipeline is available:

    params -> spc (prox) -> palette_quantize (this module) -> size report

k-means runs per layer over nonzero weights only (jit'd Lloyd iterations);
``quantized_size_bytes`` reports CSR + palette-index + Huffman-estimated
bytes (entropy bound, the standard accounting).

This module is the *offline estimate* half; the servable quantized format
is ``sparse/formats.PaletteBCSR``, built by ``sparse.compress.quantize_bcsr``
on top of ``kmeans_palette`` — see docs/size_accounting.md for how the two
accountings relate.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prox import default_regularized_predicate

PyTree = Any


def kmeans_palette(w: jax.Array, n_clusters: int, iters: int = 25,
                   seed: int = 0, chunk: int = 1 << 15):
    """Lloyd k-means over the NONZERO entries of w. Returns (palette,
    quantized w with zeros preserved, per-entry cluster assignment).

    Host-side (called at compression time, not inside a jitted step). The
    assignment step is chunked so peak memory is O(chunk * n_clusters), not
    O(n_entries * n_clusters) — at 255 clusters a full distance matrix over
    a production-size projection would be tens of GB.

    Edge cases:
      * all-zero w (a fully pruned layer / empty BCSR slice): nothing to
        cluster — returns a zero palette, w unchanged, all assignments 0;
      * fewer nonzeros (or fewer distinct values) than clusters: empty
        clusters keep their linspace init and simply go unused — the
        occupied clusters converge onto the data exactly.

    Concrete inputs only: the all-zero early-out and the palette/code
    decisions are data-dependent host control flow, so tracing this under
    jit (e.g. calling quantize from inside a sharded jitted step) would
    either crash on the bool() or silently bake one branch in. Sharded
    callers quantize on the host AFTER training (``jax.device_get``
    gathers a sharded array transparently) — that is where
    ``sparse.compress.quantize_bcsr`` calls this. To force a host callback
    from inside jit, wrap the caller in ``jax.pure_callback`` yourself.
    """
    if isinstance(w, jax.core.Tracer):
        raise TypeError(
            "kmeans_palette is host-side (data-dependent control flow) and "
            "cannot run under jit/vmap/scan tracing — call it on concrete "
            "arrays outside jit (quantize AFTER the jitted step; sharded "
            "arrays gather transparently via jax.device_get), or wrap the "
            "caller in jax.pure_callback")
    flat = w.reshape(-1).astype(jnp.float32)
    nz_mask = flat != 0
    if not bool(jnp.any(nz_mask)):
        return (jnp.zeros((n_clusters,), jnp.float32),
                jnp.zeros_like(w),
                jnp.zeros(flat.shape, jnp.int32))
    # linear init over the nonzero range (Han et al.'s best-performing init)
    lo = jnp.min(jnp.where(nz_mask, flat, jnp.inf))
    hi = jnp.max(jnp.where(nz_mask, flat, -jnp.inf))
    palette = jnp.linspace(lo, hi, n_clusters)

    n = flat.shape[0]
    chunk = min(chunk, n)
    pad = (-n) % chunk
    fc = jnp.pad(flat, (0, pad)).reshape(-1, chunk)
    mc = jnp.pad(nz_mask, (0, pad)).reshape(-1, chunk)   # pad entries masked

    def step(palette, _):
        def per_chunk(carry, xs):
            sums, counts = carry
            f, msk = xs
            a = jnp.argmin(jnp.abs(f[:, None] - palette[None, :]), axis=1)
            oh = jax.nn.one_hot(a, n_clusters, dtype=jnp.float32)
            oh = oh * msk[:, None]
            return (sums + oh.T @ f, counts + jnp.sum(oh, axis=0)), None

        zero = jnp.zeros((n_clusters,), jnp.float32)
        (sums, counts), _ = jax.lax.scan(per_chunk, (zero, zero), (fc, mc))
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), palette)
        return new, None

    palette, _ = jax.lax.scan(step, palette, None, length=iters)

    def assign_chunk(_, f):
        return None, jnp.argmin(jnp.abs(f[:, None] - palette[None, :]),
                                axis=1)

    _, assign = jax.lax.scan(assign_chunk, None, fc)
    assign = assign.reshape(-1)[:n]
    q = jnp.where(nz_mask, palette[assign], 0.0)
    return palette, q.reshape(w.shape).astype(w.dtype), assign


def quantize_tree(params: PyTree, bits: int = 4,
                  predicate=None) -> tuple[PyTree, dict]:
    """Palette-quantize every regularized weight to 2^bits clusters.
    Returns (quantized params, per-layer report)."""
    predicate = predicate or default_regularized_predicate
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, report = [], {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if predicate(name, leaf) and int(jnp.sum(leaf != 0)) > 2 ** bits:
            palette, q, assign = kmeans_palette(leaf, 2 ** bits)
            err = float(jnp.linalg.norm((q - leaf).astype(jnp.float32))
                        / max(float(jnp.linalg.norm(
                            leaf.astype(jnp.float32))), 1e-12))
            report[name] = {"bits": bits, "rel_err": err,
                            "huffman_bits": huffman_bits_estimate(
                                np.asarray(assign),
                                np.asarray(leaf.reshape(-1) != 0))}
            out.append(q)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), report


def huffman_bits_estimate(assign: np.ndarray, nz_mask: np.ndarray) -> float:
    """Entropy lower bound on Huffman-coded palette indices (nonzeros)."""
    idx = assign[nz_mask]
    if idx.size == 0:
        return 0.0
    _, counts = np.unique(idx, return_counts=True)
    p = counts / counts.sum()
    return float(idx.size * -(p * np.log2(p)).sum())


def quantized_size_bytes(params: PyTree, bits: int = 4,
                         index_bytes: int = 4,
                         reports: Optional[dict] = None) -> int:
    """Deep-compression size accounting: CSR indices + palette +
    Huffman-coded value indices for regularized layers, dense elsewhere."""
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if default_regularized_predicate(name, leaf):
            nnz = int(jnp.sum(leaf != 0))
            rows = leaf.shape[0] if leaf.ndim >= 1 else 1
            # CSR structure + palette + coded values
            structure = nnz * index_bytes + (rows + 1) * index_bytes
            palette = (2 ** bits) * leaf.dtype.itemsize
            if reports and name in reports:
                values = math.ceil(reports[name]["huffman_bits"] / 8)
            else:
                values = math.ceil(nnz * bits / 8)
            total += structure + palette + values
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
