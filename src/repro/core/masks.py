"""Zero-mask extraction and debiasing (paper §2.4 'Retraining').

Debiasing retrains the surviving (nonzero) weights with the zero pattern
frozen and the regularizer off, removing l1 shrinkage bias. Mechanically:
``mask = (w != 0)``; during retraining both grads and post-update params are
multiplied by the mask (see ProxOptimizer.update(mask=...)).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.prox import default_regularized_predicate

PyTree = Any


def zero_mask(params: PyTree, predicate: Optional[Callable] = None) -> PyTree:
    """mask leaf = 1 where weight is nonzero (or leaf not regularized)."""
    predicate = predicate or default_regularized_predicate
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if predicate(name, leaf):
            out.append((leaf != 0).astype(jnp.float32))
        else:
            out.append(jnp.ones_like(leaf, dtype=jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_mask(params: PyTree, mask: PyTree) -> PyTree:
    return jax.tree.map(lambda p, m: p * m.astype(p.dtype), params, mask)


def mask_density(mask: PyTree) -> jax.Array:
    """Fraction of kept (nonzero) weights across masked leaves."""
    kept = sum(jnp.sum(m) for m in jax.tree.leaves(mask))
    total = sum(m.size for m in jax.tree.leaves(mask))
    return kept / total
