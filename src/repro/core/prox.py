"""Proximal operators for sparse coding (paper §2.2).

The paper's central mechanism: the proximal operator of the l1 regularizer
``prox_{eta*lambda*||.||_1}(z)_i = sgn(z_i) * max(|z_i| - eta*lambda, 0)``
(soft thresholding) applied after each optimizer step, which produces *exact*
zeros during training.

Beyond the paper we add a block group-l1 prox so sparsity can be induced in
MXU-aligned blocks (TPU-native serving; see DESIGN.md §2) plus elastic-net and
hard-threshold variants used in ablations.

All operators are elementwise (or block-local) pure functions: they are
shard-invariant under any PartitionSpec and compose with pjit for free.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def soft_threshold(z: Array, tau) -> Array:
    """prox of tau*||.||_1: sgn(z) * max(|z| - tau, 0).

    Written in the min/max form of the paper's OpenCL kernel (Fig. 4):
    ``min(max(z - tau, 0), z + tau)`` — identical result, one fewer select
    on TPU's VPU than the sgn/abs form.
    """
    tau = jnp.asarray(tau, dtype=z.dtype)
    return jnp.minimum(jnp.maximum(z - tau, 0), z + tau)


def prox_l1(z: Array, tau) -> Array:
    """Alias matching the paper's notation prox_{tau*||.||_1}."""
    return soft_threshold(z, tau)


def hard_threshold(z: Array, tau) -> Array:
    """prox of the l0 pseudo-norm ball surrogate: zero out |z| <= tau.

    This is the thresholding used by the magnitude-pruning baseline (Pru).
    """
    tau = jnp.asarray(tau, dtype=z.dtype)
    return jnp.where(jnp.abs(z) > tau, z, jnp.zeros_like(z))


def prox_elastic_net(z: Array, tau_l1, tau_l2) -> Array:
    """prox of tau_l1*||.||_1 + (tau_l2/2)*||.||_2^2 (ablation regularizer)."""
    tau_l2 = jnp.asarray(tau_l2, dtype=z.dtype)
    return soft_threshold(z, tau_l1) / (1.0 + tau_l2)


def _block_reduce_l2(z: Array, block: tuple[int, int]) -> Array:
    """Per-block l2 norms for a 2D array padded to block multiples."""
    br, bc = block
    r, c = z.shape
    pr, pc = (-r) % br, (-c) % bc
    zp = jnp.pad(z, ((0, pr), (0, pc)))
    zb = zp.reshape((r + pr) // br, br, (c + pc) // bc, bc)
    return jnp.sqrt(jnp.sum(zb.astype(jnp.float32) ** 2, axis=(1, 3)))


def prox_group_l1_blocks(z: Array, tau, block: tuple[int, int] = (128, 128)) -> Array:
    """Group-l1 (block soft-threshold): shrink whole blocks toward zero.

    prox of tau * sum_g ||z_g||_2 over non-overlapping ``block`` tiles of a 2D
    weight: z_g <- z_g * max(0, 1 - tau/||z_g||_2). Whole blocks hit exact
    zero, producing BCSR-ready sparsity (beyond-paper, DESIGN.md §2).
    Non-2D inputs fall back to elementwise soft thresholding.
    """
    if z.ndim != 2:
        return soft_threshold(z, tau)
    br, bc = block
    r, c = z.shape
    norms = _block_reduce_l2(z, block)  # (R, C) block grid
    tau = jnp.asarray(tau, dtype=jnp.float32)
    scale = jnp.maximum(0.0, 1.0 - tau / jnp.maximum(norms, 1e-30))
    scale_full = jnp.repeat(jnp.repeat(scale, br, axis=0), bc, axis=1)[:r, :c]
    return (z.astype(jnp.float32) * scale_full).astype(z.dtype)


# ---------------------------------------------------------------------------
# Regularizer registry: name -> (penalty_value_fn, prox_fn)
# ---------------------------------------------------------------------------

def l1_penalty(w: Array) -> Array:
    return jnp.sum(jnp.abs(w.astype(jnp.float32)))


def group_l1_penalty(w: Array, block: tuple[int, int] = (128, 128)) -> Array:
    if w.ndim != 2:
        return l1_penalty(w)
    return jnp.sum(_block_reduce_l2(w, block))


def get_prox(name: str, **kwargs) -> Callable[[Array, Any], Array]:
    """Look up a prox operator by name ('l1', 'group_l1', 'elastic_net', 'none')."""
    if name == "l1":
        return soft_threshold
    if name == "group_l1":
        block = kwargs.get("block", (128, 128))
        return functools.partial(prox_group_l1_blocks, block=block)
    if name == "elastic_net":
        tau_l2 = kwargs.get("tau_l2", 0.0)
        return lambda z, tau: prox_elastic_net(z, tau, tau_l2)
    if name == "none":
        return lambda z, tau: z
    raise ValueError(f"unknown prox: {name!r}")


def tree_prox(params: PyTree, tau, prox_fn=soft_threshold,
              predicate: Callable[[str, Array], bool] | None = None) -> PyTree:
    """Apply a prox operator across a param pytree.

    ``predicate(path_str, leaf)`` selects which leaves are regularized; the
    default regularizes every weight *matrix* (ndim >= 2) and leaves biases,
    norm scales and other vectors untouched — matching the paper, which
    compresses conv/fc weights only (Tables A1-A4).
    """
    if predicate is None:
        predicate = default_regularized_predicate

    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path)
        out.append(prox_fn(leaf, tau) if predicate(name, leaf) else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


_NEVER_REGULARIZE = ("bias", "scale", "norm", "ln_", "_a_param", "decay",
                     "time_decay", "time_first", "pos_emb", "rglru_a")


def default_regularized_predicate(name: str, leaf: Array) -> bool:
    """Regularize weight matrices/filters only (paper compresses conv+fc)."""
    lname = name.lower()
    if any(k in lname for k in _NEVER_REGULARIZE):
        return False
    return leaf.ndim >= 2
