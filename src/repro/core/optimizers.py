"""Prox-RMSProp (paper Alg. 1), Prox-ADAM (paper Alg. 2) and Prox-SGD.

Implemented as self-contained optax-style GradientTransformations (pure
``init``/``update`` pairs over pytrees, no optax dependency). Each update is:

    step:  d_t from the base rule (SGD / RMSProp / ADAM)
    w_t <- prox_{eta_t * lambda * ||.||_1}( w_{t-1} - eta_t * d_t )

i.e. the prox is applied to the *post-step iterate* with threshold
``eta_t * lambda`` exactly as in the paper's Algorithms 1-2. lambda may follow
a schedule (core/schedule.py). A ``mask`` pytree (0/1 per element) supports
the debiasing phase: masked entries receive zero updates and stay zero.

The elementwise inner update can be routed through the fused Pallas kernel
(`repro.kernels.prox_adam`) with ``use_fused_kernel=True``; the pure-jnp path
here is the oracle the kernel is tested against.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import prox as prox_lib

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]  # step -> value


def _as_schedule(v) -> Schedule:
    if callable(v):
        return v
    return lambda step: jnp.asarray(v, dtype=jnp.float32)


class ProxState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: PyTree                # 1st moment (zeros pytree for rmsprop/sgd)
    v: PyTree                # 2nd moment (zeros pytree for sgd)


@dataclasses.dataclass(frozen=True)
class ProxOptimizer:
    """A (init, update) pair. ``update`` returns (new_params, new_state)."""
    init: Callable[[PyTree], ProxState]
    update: Callable[..., tuple[PyTree, ProxState]]
    name: str = "prox_opt"


def _zeros_like_tree(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _make(name: str,
          direction_fn: Callable,
          learning_rate,
          lam,
          prox_name: str = "l1",
          prox_kwargs: Optional[dict] = None,
          prox_fn: Optional[Callable] = None,
          regularized_predicate=None,
          weight_decay: float = 0.0) -> ProxOptimizer:
    """``prox_fn`` overrides the registry lookup; a prox accepting a ``path``
    keyword is called with the leaf's tree path — the hook that lets
    ``sparse.compress.make_plan_prox`` apply block group-l1 on the exact
    (out, in) BCSR grid per weight (SpC-Retrain trains into BlockCSR)."""
    lr_s = _as_schedule(learning_rate)
    lam_s = _as_schedule(lam)
    if prox_fn is None:
        prox_fn = prox_lib.get_prox(prox_name, **(prox_kwargs or {}))
    try:
        path_aware = "path" in inspect.signature(prox_fn).parameters
    except (TypeError, ValueError):
        path_aware = False
    predicate = regularized_predicate or prox_lib.default_regularized_predicate

    def init(params: PyTree) -> ProxState:
        return ProxState(step=jnp.zeros((), jnp.int32),
                         m=_zeros_like_tree(params),
                         v=_zeros_like_tree(params))

    def update(grads: PyTree, state: ProxState, params: PyTree,
               mask: Optional[PyTree] = None) -> tuple[PyTree, ProxState]:
        t = state.step + 1
        eta = lr_s(t)
        tau = eta * lam_s(t)

        if mask is not None:
            grads = jax.tree.map(lambda g, mk: g * mk.astype(g.dtype), grads, mask)

        flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)

        new_p, new_m, new_v = [], [], []
        for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            name_str = jax.tree_util.keystr(path)
            g32 = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p32
            d, m2, v2 = direction_fn(g32, m, v, t)
            z = p32 - eta * d
            if predicate(name_str, p):
                z = (prox_fn(z, tau, path=name_str) if path_aware
                     else prox_fn(z, tau))
            new_p.append(z.astype(p.dtype))
            new_m.append(m2)
            new_v.append(v2)

        if mask is not None:
            flat_mask = treedef.flatten_up_to(mask)
            new_p = [q * mk.astype(q.dtype) for q, mk in zip(new_p, flat_mask)]

        return (jax.tree_util.tree_unflatten(treedef, new_p),
                ProxState(step=t,
                          m=jax.tree_util.tree_unflatten(treedef, new_m),
                          v=jax.tree_util.tree_unflatten(treedef, new_v)))

    return ProxOptimizer(init=init, update=update, name=name)


# ---------------------------------------------------------------------------
# The three rules
# ---------------------------------------------------------------------------

def prox_sgd(learning_rate, lam=0.0, momentum: float = 0.0, **kw) -> ProxOptimizer:
    """Prox-SGD (stochastic proximal gradient, paper Eq. (2))."""
    def direction(g, m, v, t):
        if momentum:
            m2 = momentum * m + g
            return m2, m2, v
        return g, m, v
    return _make("prox_sgd", direction, learning_rate, lam, **kw)


def prox_rmsprop(learning_rate, lam=0.0, beta: float = 0.9,
                 eps: float = 1e-8, **kw) -> ProxOptimizer:
    """Prox-RMSProp — paper Algorithm 1.

    v_t = beta*v + (1-beta)*g^2 ; w <- prox(w - eta * g/(sqrt(v_t)+eps)).
    """
    def direction(g, m, v, t):
        v2 = beta * v + (1.0 - beta) * g * g
        return g / (jnp.sqrt(v2) + eps), m, v2
    return _make("prox_rmsprop", direction, learning_rate, lam, **kw)


def prox_adam(learning_rate, lam=0.0, b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8, **kw) -> ProxOptimizer:
    """Prox-ADAM — paper Algorithm 2 (with bias correction)."""
    def direction(g, m, v, t):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        tf = t.astype(jnp.float32)
        mhat = m2 / (1.0 - jnp.power(b1, tf))
        vhat = v2 / (1.0 - jnp.power(b2, tf))
        return mhat / (jnp.sqrt(vhat) + eps), m2, v2
    return _make("prox_adam", direction, learning_rate, lam, **kw)


_REGISTRY = {"prox_sgd": prox_sgd, "prox_rmsprop": prox_rmsprop,
             "prox_adam": prox_adam, "sgd": prox_sgd,
             "rmsprop": prox_rmsprop, "adam": prox_adam}


def get_optimizer(name: str, **kwargs) -> ProxOptimizer:
    if name not in _REGISTRY:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
