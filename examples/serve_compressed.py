"""Serving with compressed (BCSR) weights — the paper's inference path on
the TPU-adapted block-sparse format.

Trains briefly with group-l1 (block) sparse coding so sparsity lands in
MXU-shaped blocks, converts the FFN weights to BlockCSR, and compares dense
vs compressed forward outputs + memory footprints.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers import prox_adam
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models.model_zoo import build
from repro.sparse.formats import bcsr_density, dense_to_bcsr
from repro.sparse.ops import sparse_matmul
from repro.train.loop import LoopConfig, train_loop
from repro.train.state import TrainState
from repro.train.step import make_train_step

BLOCK = (16, 16)   # reduced-model block; production uses (128, 128)


def main():
    model = build("smollm-360m", reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    data = TokenStreamConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)

    # group-l1 at block granularity: whole MXU tiles go to zero
    # (lam calibrated so ~40-60% of blocks die on this reduced model)
    opt = prox_adam(3e-3, lam=1.2, prox_name="group_l1",
                    prox_kwargs={"block": BLOCK})
    state = TrainState.create(params, opt)
    step = jax.jit(make_train_step(model, opt))
    state, hist = train_loop(step, state, lambda s: token_batch(data, s),
                             LoopConfig(total_steps=150, log_every=50))

    # convert every FFN wi to BCSR and compare dense vs kernel path
    total_dense, total_bcsr = 0, 0
    layers = state.params["layers"]
    wi = np.asarray(layers["b0_attn"]["mlp"]["wi"])[0]     # first layer
    w_t = wi.T.copy()                                       # (out, in)
    m = dense_to_bcsr(w_t, BLOCK)
    print(f"block density of trained wi: {bcsr_density(m):.2f} "
          f"({m.n_blocks} nonzero {BLOCK} blocks)")
    x = jax.random.normal(jax.random.PRNGKey(1), (8, wi.shape[0]))
    y_dense = x @ jnp.asarray(wi)
    y_sparse = sparse_matmul(x, m, backend="pallas")
    err = float(jnp.max(jnp.abs(y_dense - y_sparse)))
    print(f"dense vs BCSR-kernel max err: {err:.2e}")
    print(f"weight bytes: dense={w_t.size*4} bcsr={m.nbytes} "
          f"({w_t.size*4/max(m.nbytes,1):.1f}x smaller)")


if __name__ == "__main__":
    main()
