"""Paper experiment end-to-end: LeNet-5 on (synthetic) MNIST with the full
method comparison — SpC vs SpC(Retrain) vs Pru vs Pru(Retrain) vs MM — i.e.
one run reproducing the structure of the paper's Table 1 + Table 2 row.

    PYTHONPATH=src python examples/paper_cnn_pipeline.py
"""
import jax

from benchmarks.common import (data_for, evaluate_cnn, make_cnn_step,
                               spc_with_retrain, train_cnn)
from repro.core import masks, metrics, mm, pruning
from repro.core.optimizers import prox_adam
from repro.data.synthetic import image_batch
from repro.models.cnn import CNN_ZOO
from repro.train.losses import softmax_xent

STEPS = 250


def main():
    model = CNN_ZOO["lenet5"]
    data_cfg = data_for(model)
    rows = []

    # reference
    ref, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), STEPS)
    ref_acc = evaluate_cnn(model, ref, data_cfg)
    rows.append(("reference", ref_acc, 0.0))

    # SpC / SpC(Retrain)
    out = spc_with_retrain(model, lam=1.0, steps=STEPS, retrain_steps=100)
    rows.append(("SpC", evaluate_cnn(model, out["spc_params"], data_cfg),
                 out["spc_compression"]))
    rows.append(("SpC(Retrain)",
                 evaluate_cnn(model, out["retrain_params"], data_cfg),
                 out["retrain_compression"]))

    # Pru / Pru(Retrain) at matched compression
    pruned = pruning.magnitude_prune_global(ref, out["spc_compression"])
    rows.append(("Pru", evaluate_cnn(model, pruned, data_cfg),
                 metrics.compression_rate(pruned)))
    mask = masks.zero_mask(pruned)
    pr_rt, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), 100,
                         params=pruned, mask=mask)
    rows.append(("Pru(Retrain)", evaluate_cnn(model, pr_rt, data_cfg),
                 metrics.compression_rate(pr_rt)))

    # MM (needs the pretrained reference, as in the paper)
    cfg = mm.MMConfig(alpha=1e-2, mu0=0.3, mu_growth=1.2, mu_every=30,
                      c_step_every=30, learning_rate=2e-3)
    state = mm.mm_init(ref, cfg)
    p = ref

    @jax.jit
    def mm_step(p, s, b):
        g = jax.grad(lambda q: softmax_xent(model.apply(q, b["inputs"]),
                                            b["labels"]))(p)
        return mm.mm_update(g, s, p, cfg)

    for s in range(STEPS):
        p, state = mm_step(p, state, image_batch(data_cfg, s))
    final = mm.mm_final_params(p, state)
    rows.append(("MM", evaluate_cnn(model, final, data_cfg),
                 metrics.compression_rate(final)))

    print(f"{'method':14s} {'accuracy':>9s} {'compression':>12s}")
    for name, acc, comp in rows:
        print(f"{name:14s} {acc:9.4f} {100*comp:11.1f}%")


if __name__ == "__main__":
    main()
