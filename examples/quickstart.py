"""Quickstart: compressed learning (the paper's method) in ~40 lines.

Trains a reduced SmolLM with Prox-ADAM (l1 sparse coding), inspects the
layer-wise compression table, debias-retrains, and runs greedy decoding on
the compressed model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import metrics
from repro.core.optimizers import prox_adam
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models.model_zoo import build
from repro.serve.step import generate
from repro.train.loop import run_spc_pipeline
from repro.train.step import make_train_step


def main():
    model = build("smollm-360m", reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    data = TokenStreamConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)

    # The paper's pipeline: l1 sparse coding with Prox-ADAM, then debiasing.
    state, hist, hist_db, report = run_spc_pipeline(
        params,
        make_train_step=lambda opt: jax.jit(make_train_step(model, opt)),
        opt_spc=prox_adam(3e-3, lam=1.5),       # lambda controls compression
        opt_debias=prox_adam(1e-3, lam=0.0),    # retrain survivors, no reg
        batch_fn=lambda s: token_batch(data, s),
        spc_steps=120, debias_steps=40, log_every=30)

    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(debias -> {hist_db[-1]['loss']:.3f})")
    print(f"compression: {100*report['spc']['compression_rate']:.1f}% "
          f"({report['spc']['x_factor']:.0f}x fewer weights)")
    print(metrics.format_table(metrics.layer_compression(state.params),
                               "\nlayer-wise:"))

    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    tokens = generate(model, state.params, prompt, steps=12)
    print("\ngenerated with the compressed model:", tokens[0].tolist())


if __name__ == "__main__":
    main()
