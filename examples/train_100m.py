"""End-to-end driver: train a ~100M-param SmolLM-family model with
compressed learning for a few hundred steps.

This is the assignment's "train ~100M model" driver. The full 100M config
is the default; on this CPU container pass --tiny to run the reduced config
in minutes (the code path is identical — same model family, optimizer,
data pipeline, checkpointing).

    PYTHONPATH=src python examples/train_100m.py --tiny --steps 200
    PYTHONPATH=src python examples/train_100m.py --steps 300     # full 100M
"""
import argparse
import dataclasses
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.core import metrics
from repro.core.optimizers import prox_adam
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models.model_zoo import build
from repro.train.loop import LoopConfig, train_loop
from repro.train.state import TrainState
from repro.train.step import make_train_step


def config_100m():
    """SmolLM-family ~100M: 12L x 768 wide (llama-style GQA)."""
    base = get_config("smollm-360m")
    return dataclasses.replace(
        base, name="smollm-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=49152,
        compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    model = build(cfg, reduced=args.tiny)
    cfg = model.cfg
    if args.tiny:
        args.seq = min(args.seq, 64)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    opt = prox_adam(3e-4, lam=args.lam)
    state = TrainState.create(params, opt)
    data = TokenStreamConfig(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    ckpt = Checkpointer(args.ckpt_dir, keep_n=2)

    t0 = time.time()
    state, hist = train_loop(
        step, state, lambda s: token_batch(data, s),
        LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=20),
        checkpointer=ckpt,
        metrics_cb=lambda s, m: print(
            f"  step {s:4d} loss {m['loss']:.4f} |g| {m['grad_norm']:.2f}"))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done in {dt:.1f}s ({toks/dt:.0f} tok/s); "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"compression: "
          f"{100*metrics.compression_rate(state.params):.1f}%")


if __name__ == "__main__":
    main()
