"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Roofline terms for the big
architectures come from the dry-run artifacts (launch/dryrun.py) and are
appended when experiments/dryrun/ exists.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time

MODULES = [
    "benchmarks.optimizer_variance",      # paper Fig. 5
    "benchmarks.compression_sweep",       # paper Fig. 6 + Table 1
    "benchmarks.retraining",              # paper Fig. 7
    "benchmarks.mm_comparison",           # paper Table 2 + Fig. 8
    "benchmarks.layerwise_compression",   # paper Tables A1-A4
    "benchmarks.inference_speedup",       # paper Table 3
    "benchmarks.kernel_bench",            # kernels
]


def dryrun_rows(root="experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(root, "*.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            rows.append({"name": f"dryrun/{r['cell']}", "us_per_call": 0.0,
                         "derived": f"FAILED:{r.get('error', '')[:80]}"})
            continue
        roof = r["roofline"]
        rows.append({
            "name": f"dryrun/{r['cell']}",
            "us_per_call": roof["bound_s"] * 1e6,
            "derived": (f"dominant={roof['dominant']},"
                        f"compute_s={roof['compute_s']:.4f},"
                        f"memory_s={roof['memory_s']:.4f},"
                        f"collective_s={roof['collective_s']:.4f},"
                        f"useful={roof['useful_flops_ratio']:.3f},"
                        f"mem_gb={r['memory']['peak_per_device_gb']:.2f}"),
        })
    return rows


def main() -> None:
    import importlib
    print("name,us_per_call,derived")
    t_all = time.time()
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.2f},"
                      f"\"{row['derived']}\"")
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{modname},0,\"ERROR:{type(e).__name__}:{e}\"")
    for row in dryrun_rows():
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
    print(f"# total wall time: {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
