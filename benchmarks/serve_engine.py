"""Continuous-batching engine benchmark: aggregate tokens/s and p50/p95
latency at several request mixes, engine vs the sequential single-request
``generate`` path, on dense / BlockCSR / PaletteBCSR weights — for the
attention reference arch (smollm) and the recurrent archs the slot-state
pools brought under the engine (rwkv6-3b, recurrentgemma-9b). Each row
also records the pool byte split (KV pages vs recurrent state slots).

The headline number is the batching win on the compressed serving path:
one engine tick decodes every active slot in a single jitted dispatch,
so aggregate compressed-decode tokens/s should beat running the same
requests one-by-one through ``generate`` (whose per-token dispatch cost is
the same but amortized over batch=1).

    PYTHONPATH=src python -m benchmarks.serve_engine --json BENCH_engine.json

Rows follow the BENCH json schema (``name`` / ``us_per_call`` /
``derived``), same as ``benchmarks.inference_speedup`` — CI uploads the
JSON alongside ``BENCH_pr.json``. ``--assert-speedup`` exits nonzero if
the batched compressed engine fails to beat sequential compressed serving
(the acceptance gate for the engine's reason to exist).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

# request mixes: (name, [(prompt_len, gen), ...])
MIXES = {
    "decode_heavy": [(8, 24)] * 8,
    "mixed_len": [(8, 16)] * 4 + [(48, 16)] * 4,
    "prefill_heavy": [(64, 8)] * 8,
}
# recurrent archs ride the decode-heavy mix (state pools are O(1) per
# slot, so decode is where the slot-batching win lives)
RECURRENT_ARCHS = ("rwkv6-3b", "recurrentgemma-9b")


def _requests(mix, vocab: int):
    import jax

    out = []
    for i, (plen, gen) in enumerate(mix):
        ids = np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(1234), i), (plen,),
            0, vocab), np.int32)
        out.append((ids, gen))
    return out


def _engine_stats(model, params, requests, *, max_batch=8, prefill_chunk=16,
                  page_size=16):
    """Warm run (compile both tick widths) then a timed run on the same
    engine instance — the jitted mixed step is per-engine, so reuse keeps
    compile time out of the measurement."""
    from repro.serve.engine import EngineConfig, ServeEngine

    max_seq = max(len(p) + g for p, g in requests)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=max_batch,
                                   prefill_chunk=prefill_chunk,
                                   page_size=page_size, max_seq_len=max_seq))
    eng.run(requests)                       # warm-up: compiles + first pass
    runs = [eng.run(requests)["stats"] for _ in range(2)]
    return max(runs, key=lambda s: s["tok_s"])   # best-of-2: shave OS noise


def _sequential_tok_s(model, params, requests):
    """Single-request baseline: the same requests served one at a time
    through persistent jitted prefill/decode (compile excluded — shapes are
    warmed before timing)."""
    import jax
    import jax.numpy as jnp

    from repro.serve.step import make_decode_step

    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model))

    def one(ids, gen):
        cache = model.init_cache(1, len(ids) + gen)
        logits, cache = prefill(params, jnp.asarray(ids)[None, :], cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(len(ids), len(ids) + gen - 1):
            tok, _, cache = decode(params, tok[:, None], cache, jnp.int32(t))
        return tok

    for ids, gen in requests:               # warm every (shape) variant
        jax.block_until_ready(one(ids, gen))
    best = float("inf")
    for _ in range(2):                      # best-of-2: shave OS noise
        t0 = time.perf_counter()
        for ids, gen in requests:
            jax.block_until_ready(one(ids, gen))
        best = min(best, time.perf_counter() - t0)
    return sum(g for _, g in requests) / best


def run():
    import jax

    from repro.models.model_zoo import build
    from repro.sparse.compress import (CompressionPlan, compress_params,
                                       prune_blocks_for_plan,
                                       quantize_compressed)

    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.5)
    pruned = prune_blocks_for_plan(params, plan, 0.85)
    cp = compress_params(pruned, plan)
    formats = {"dense": pruned, "bcsr": cp,
               "palette8": quantize_compressed(cp, bits=8)}
    # dense only on one mix (it is the reference point, not the product)
    cells = [(mix, fmt) for mix in MIXES for fmt in ("bcsr", "palette8")]
    cells.append(("mixed_len", "dense"))

    rows = []
    for mix_name, fmt in cells:
        requests = _requests(MIXES[mix_name], model.cfg.vocab)
        p = formats[fmt]
        s = _engine_stats(model, p, requests)
        seq_tok_s = _sequential_tok_s(model, p, requests)
        rows.append(_row(f"serve_engine/{mix_name}_{fmt}", s, seq_tok_s))

    # recurrent archs under the engine (slot-state pools): BCSR-compressed,
    # decode-heavy mix — the --assert-speedup gate covers these rows too
    for arch in RECURRENT_ARCHS:
        rmodel = build(arch, reduced=True)
        rplan = CompressionPlan(block=(8, 64), min_sparsity=0.3,
                                min_size=4096)
        rpruned = prune_blocks_for_plan(rmodel.init(jax.random.PRNGKey(0)),
                                        rplan, 0.75)
        rcp = compress_params(rpruned, rplan)
        requests = _requests(MIXES["decode_heavy"], rmodel.cfg.vocab)
        s = _engine_stats(rmodel, rcp, requests)
        seq_tok_s = _sequential_tok_s(rmodel, rcp, requests)
        rows.append(_row(f"serve_engine/{arch}_decode_heavy_bcsr",
                         s, seq_tok_s))
    return rows


def _row(name, s, seq_tok_s):
    return {
        "name": name,
        "us_per_call": 1e6 / max(s["tok_s"], 1e-9),
        "derived": (f"engine_tok_s={s['tok_s']:.1f},"
                    f"seq_tok_s={seq_tok_s:.1f},"
                    f"batch_speedup={s['tok_s']/max(seq_tok_s,1e-9):.2f}x,"
                    f"ttft_p50_ms={s['ttft_p50_s']*1e3:.1f},"
                    f"ttft_p95_ms={s['ttft_p95_s']*1e3:.1f},"
                    f"lat_p50_ms={s['latency_p50_s']*1e3:.1f},"
                    f"lat_p95_ms={s['latency_p95_s']*1e3:.1f},"
                    f"n_ticks={s['n_ticks']},"
                    f"n_prefill_chunks={s['n_prefill_chunks']},"
                    f"kv_pool_bytes={s['kv_page_bytes']},"
                    f"state_pool_bytes={s['state_slot_bytes']}")}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the rows to this path (BENCH json schema; "
                         "CI uploads it alongside BENCH_pr.json)")
    ap.add_argument("--assert-speedup", action="store_true",
                    help="exit nonzero unless the batched compressed engine "
                         "beats sequential compressed serving (aggregate "
                         "tokens/s) on every decode-dominated compressed "
                         "cell — attention AND recurrent (rwkv/"
                         "recurrentgemma) rows (prefill_heavy is reported "
                         "but not gated: a one-shot sequential prefill is "
                         "a single big dispatch and legitimately wins on "
                         "CPU)")
    ap.add_argument("--assert-from", default="",
                    help="apply --assert-speedup to rows loaded from this "
                         "previously written --json file instead of "
                         "re-running the benchmark — lets CI upload the "
                         "artifact first and gate afterwards, so a failed "
                         "gate still leaves the numbers to diagnose")
    args = ap.parse_args(argv)
    if args.assert_from:
        with open(args.assert_from) as f:
            rows = json.load(f)["rows"]
        args.assert_speedup = True
    else:
        rows = run()
        for r in rows:
            print(r)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"rows": rows}, f, indent=1)
            print(f"wrote {args.json}")
    if args.assert_speedup:
        import re

        bad = [r["name"] for r in rows
               if "dense" not in r["name"]
               and "prefill_heavy" not in r["name"]
               and float(re.search(r"batch_speedup=([0-9.]+)x",
                                   r["derived"]).group(1)) <= 1.0]
        if bad:
            print(f"FAIL: batched engine did not beat sequential serving "
                  f"on {bad}")
            return 1
        print("batched compressed engine > sequential on every "
              "decode-dominated compressed cell")
    return 0


if __name__ == "__main__":
    sys.exit(main())
