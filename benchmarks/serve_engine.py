"""Continuous-batching engine benchmark: aggregate tokens/s and p50/p95
latency at several request mixes, engine vs the sequential single-request
``generate`` path, on dense / BlockCSR / PaletteBCSR weights — for the
attention reference arch (smollm) and the recurrent archs the slot-state
pools brought under the engine (rwkv6-3b, recurrentgemma-9b). Each row
also records the pool byte split (KV pages vs recurrent state slots).

The headline number is the batching win on the compressed serving path:
one engine tick decodes every active slot in a single jitted dispatch,
so aggregate compressed-decode tokens/s should beat running the same
requests one-by-one through ``generate`` (whose per-token dispatch cost is
the same but amortized over batch=1).

    PYTHONPATH=src python -m benchmarks.serve_engine --json BENCH_engine.json

Rows follow the BENCH json schema (``name`` / ``us_per_call`` /
``derived``), same as ``benchmarks.inference_speedup`` — CI uploads the
JSON alongside ``BENCH_pr.json``. ``--assert-speedup`` exits nonzero if
the batched compressed engine fails to beat sequential compressed serving
(the acceptance gate for the engine's reason to exist), and also gates the
router lanes (serve/router.py): 2-replica aggregate tokens/s scaling,
prefix-affinity retention of the warm-TTFT win vs the round-robin control,
and per-token parity across a forced replica failure + re-dispatch.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

# request mixes: (name, [(prompt_len, gen), ...])
MIXES = {
    "decode_heavy": [(8, 24)] * 8,
    "mixed_len": [(8, 16)] * 4 + [(48, 16)] * 4,
    "prefill_heavy": [(64, 8)] * 8,
}
# shared-prefix mix: a 96-token shared system prompt + 8-token distinct
# tails (page_size 16 -> the shared prefix is exactly 6 immutable pages)
SHARED_PREFIX = dict(n=8, shared_len=96, tail_len=8, gen=8, page_size=16)
# router lanes: replica scaling on a decode-heavy mix, prefix-affinity
# retention on the shared-prefix mix, forced-failure re-dispatch parity
ROUTER = dict(n=16, prompt_len=8, gen=24, max_batch=8, page_size=16)
# recurrent archs ride the decode-heavy mix (state pools are O(1) per
# slot, so decode is where the slot-batching win lives)
RECURRENT_ARCHS = ("rwkv6-3b", "recurrentgemma-9b")


def _requests(mix, vocab: int):
    import jax

    out = []
    for i, (plen, gen) in enumerate(mix):
        ids = np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(1234), i), (plen,),
            0, vocab), np.int32)
        out.append((ids, gen))
    return out


def _engine_stats(model, params, requests, *, max_batch=8, prefill_chunk=16,
                  page_size=16):
    """Warm run (compile both tick widths) then a timed run on the same
    engine instance — the jitted mixed step is per-engine, so reuse keeps
    compile time out of the measurement."""
    from repro.serve.engine import EngineConfig, ServeEngine

    max_seq = max(len(p) + g for p, g in requests)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=max_batch,
                                   prefill_chunk=prefill_chunk,
                                   page_size=page_size, max_seq_len=max_seq))
    eng.run(requests)                       # warm-up: compiles + first pass
    runs = [eng.run(requests)["stats"] for _ in range(2)]
    best = max(runs, key=lambda s: s["tok_s"])   # best-of-2: shave OS noise
    return best, eng


def _sequential_tok_s(model, params, requests):
    """Single-request baseline: the same requests served one at a time
    through persistent jitted prefill/decode (compile excluded — shapes are
    warmed before timing)."""
    import jax
    import jax.numpy as jnp

    from repro.serve.step import make_decode_step

    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model))

    def one(ids, gen):
        cache = model.init_cache(1, len(ids) + gen)
        logits, cache = prefill(params, jnp.asarray(ids)[None, :], cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(len(ids), len(ids) + gen - 1):
            tok, _, cache = decode(params, tok[:, None], cache, jnp.int32(t))
        return tok

    for ids, gen in requests:               # warm every (shape) variant
        jax.block_until_ready(one(ids, gen))
    best = float("inf")
    for _ in range(2):                      # best-of-2: shave OS noise
        t0 = time.perf_counter()
        for ids, gen in requests:
            jax.block_until_ready(one(ids, gen))
        best = min(best, time.perf_counter() - t0)
    return sum(g for _, g in requests) / best


def _shared_prefix_row(model, params, fmt: str):
    """Prefix-cache lane: three waves through ONE engine. Wave A warms the
    compile caches (and populates the radix tree with its own prefix);
    wave B runs a fresh shared prefix cold (no hits); wave C reuses wave
    B's prefix with new tails (hits). ``prefix_ttft_speedup`` = cold p50
    TTFT / warm p50 TTFT — a same-run ratio, so machine speed cancels."""
    import jax

    from repro.serve.engine import EngineConfig, ServeEngine

    sp = SHARED_PREFIX
    vocab = model.cfg.vocab

    def rand(tag: str, n: int):
        key = jax.random.PRNGKey(abs(hash(tag)) % 2**31)
        return np.asarray(jax.random.randint(key, (n,), 0, vocab), np.int32)

    def wave(prefix_tag: str, tail_tag: str):
        shared = rand(prefix_tag, sp["shared_len"])
        return [{"prompt": np.concatenate(
                     [shared, rand(f"{tail_tag}/{i}", sp["tail_len"])]),
                 "max_new_tokens": sp["gen"]} for i in range(sp["n"])]

    eng = ServeEngine(model, params, EngineConfig(
        max_batch=sp["n"], prefill_chunk=16, page_size=sp["page_size"],
        max_seq_len=sp["shared_len"] + sp["tail_len"] + sp["gen"],
        prefix_cache=True))
    eng.run(wave("A", "a"))                       # warm-up (compile) wave
    cold = eng.run(wave("B", "b"))["stats"]       # fresh prefix: no hits
    warm = eng.run(wave("B", "c"))["stats"]       # same prefix, new tails
    assert cold["n_cached_tokens"] == 0, "cold wave unexpectedly hit"
    assert warm["n_cached_tokens"] > 0, "warm wave missed the cache"
    hit_rate = warm["n_cached_tokens"] / warm["n_prompt"]
    speedup = cold["ttft_p50_s"] / max(warm["ttft_p50_s"], 1e-9)
    return {
        "name": f"serve_engine/shared_prefix_{fmt}",
        "us_per_call": 1e6 / max(warm["tok_s"], 1e-9),
        "derived": (f"prefix_ttft_speedup={speedup:.2f}x,"
                    f"prefix_hit_rate={hit_rate:.3f},"
                    f"cold_ttft_p50_ms={cold['ttft_p50_s']*1e3:.1f},"
                    f"warm_ttft_p50_ms={warm['ttft_p50_s']*1e3:.1f},"
                    f"cold_ttft_p95_ms={cold['ttft_p95_s']*1e3:.1f},"
                    f"warm_ttft_p95_ms={warm['ttft_p95_s']*1e3:.1f},"
                    f"n_cached_tokens={warm['n_cached_tokens']},"
                    f"engine_tok_s={warm['tok_s']:.1f}")}


def _mixed_priority_row(model, params, fmt: str):
    """Priority/preemption lane: 6 batch-class requests saturate 4 slots,
    then 2 interactive requests arrive mid-run and preempt — the row
    records p50/p95 TTFT per class (measured from each request's arrival)
    and the preemption count."""
    import jax

    from repro.serve import api
    from repro.serve.engine import EngineConfig, ServeEngine

    vocab = model.cfg.vocab
    prompts = [np.asarray(jax.random.randint(
                   jax.random.fold_in(jax.random.PRNGKey(77), i), (8,),
                   0, vocab), np.int32) for i in range(8)]
    eng = ServeEngine(model, params, EngineConfig(
        max_batch=4, prefill_chunk=16, page_size=16, max_seq_len=48))

    def one_pass():
        finished = []
        preempt0 = eng.scheduler.n_preemptions
        t0 = time.perf_counter()
        for i in range(6):
            eng.submit(api.Request(prompt=prompts[i], max_new_tokens=24,
                                   priority="batch"))
        for _ in range(6):                 # batch requests get going
            finished.extend(eng.step())
        for i in range(6, 8):              # interactive arrivals preempt
            eng.submit(api.Request(prompt=prompts[i], max_new_tokens=8,
                                   priority="interactive"))
        while eng.scheduler.has_work():
            finished.extend(eng.step())
        s = eng._stats(finished, time.perf_counter() - t0)
        s["n_preemptions"] -= preempt0     # per-pass (the engine is reused)
        return s

    one_pass()                             # warm-up: compile both widths
    s = one_pass()
    by = {c: s["by_class"].get(c) for c in (0, 2)}
    parts = [f"engine_tok_s={s['tok_s']:.1f},n_preemptions={s['n_preemptions']}"]
    for c, label in ((0, "interactive"), (2, "batch")):
        cs = by[c]
        parts.append(f"{label}_ttft_p50_ms={cs['ttft_p50_s']*1e3:.1f},"
                     f"{label}_ttft_p95_ms={cs['ttft_p95_s']*1e3:.1f},"
                     f"{label}_latency_p50_ms={cs['latency_p50_s']*1e3:.1f}")
    return {"name": f"serve_engine/mixed_priority_{fmt}",
            "us_per_call": 1e6 / max(s["tok_s"], 1e-9),
            "derived": ",".join(parts)}


def _router_scale_row(model, params, fmt: str):
    """Replica-scaling lane: the same decode-heavy request mix through the
    router at 1 and 2 replicas (least-loaded dispatch). ``router_scale`` is
    the 2-replica / 1-replica aggregate tokens/s ratio of the same run —
    the number compression's smaller-model-more-replicas payoff rides on.
    Thread-replica scaling needs idle cores (the jitted step releases the
    GIL into XLA); ``n_cpus`` is recorded so the gate can account for
    single-core machines, where replicas time-slice one core."""
    import os

    from repro.serve.api import Request
    from repro.serve.engine import EngineConfig
    from repro.serve.router import Router

    rc = ROUTER
    reqs = [Request(prompt=p, max_new_tokens=g)
            for p, g in _requests([(rc["prompt_len"], rc["gen"])] * rc["n"],
                                  model.cfg.vocab)]
    cfg = EngineConfig(max_batch=rc["max_batch"], prefill_chunk=16,
                       page_size=rc["page_size"],
                       max_seq_len=rc["prompt_len"] + rc["gen"])
    tok = {}
    for n in (1, 2):
        router = Router.build(model, params, cfg, n, policy="least-loaded")
        router.serve(reqs)                  # warm-up: compile every replica
        tok[n] = max(router.serve(reqs)["stats"]["tok_s"]
                     for _ in range(2))     # best-of-2: shave OS noise
    scale = tok[2] / max(tok[1], 1e-9)
    return {"name": f"serve_engine/router_scale_{fmt}",
            "us_per_call": 1e6 / max(tok[2], 1e-9),
            "derived": (f"router_scale={scale:.2f}x,"
                        f"router_tok_s_1={tok[1]:.1f},"
                        f"router_tok_s_2={tok[2]:.1f},"
                        f"n_cpus={os.cpu_count() or 1}")}


def _router_affinity_row(model, params, fmt: str):
    """Prefix-affinity lane. Per policy (2-replica prefix vs round-robin
    control, plus the 1-replica reference): a warm-up wave compiles both
    replicas, ONE cold probe request caches the shared prefix on exactly
    one replica, then a warm wave of n same-prefix requests measures
    warm TTFT. Affinity routing sends the whole warm wave to the caching
    replica (hits); round-robin sprays it, and the half that lands cold
    re-prefills the prefix it just paid for. ``affinity_retention`` =
    2-replica-affinity warm-TTFT speedup / 1-replica warm-TTFT speedup —
    the fraction of the single-engine prefix-cache win that survives going
    multi-replica (same-run ratio, machine-corrected)."""
    import jax

    from repro.serve.api import Request
    from repro.serve.engine import EngineConfig
    from repro.serve.router import Router

    sp = SHARED_PREFIX
    vocab = model.cfg.vocab

    def rand(tag: str, n: int):
        key = jax.random.PRNGKey(abs(hash(tag)) % 2**31)
        return np.asarray(jax.random.randint(key, (n,), 0, vocab), np.int32)

    def wave(prefix_tag: str, tail_tag: str, n: int):
        shared = rand(prefix_tag, sp["shared_len"])
        return [Request(prompt=np.concatenate(
                    [shared, rand(f"{tail_tag}/{i}", sp["tail_len"])]),
                    max_new_tokens=sp["gen"]) for i in range(n)]

    cfg = EngineConfig(max_batch=sp["n"], prefill_chunk=16,
                       page_size=sp["page_size"],
                       max_seq_len=sp["shared_len"] + sp["tail_len"]
                       + sp["gen"], prefix_cache=True)

    def measure(n_replicas: int, policy: str):
        router = Router.build(model, params, cfg, n_replicas, policy=policy)
        # warm-up: fully distinct prompts spread over (and compile) all
        # replicas under every policy
        router.serve([Request(prompt=rand(f"W/{i}",
                                          sp["shared_len"] + sp["tail_len"]),
                              max_new_tokens=sp["gen"])
                      for i in range(2 * sp["n"])])
        cold = router.serve(wave("S", "cold", 1))["completions"]
        warm = router.serve(wave("S", "warm", sp["n"]))["completions"]
        hit = (sum(c.n_cached for c in warm)
               / max(sum(c.n_prompt for c in warm), 1))
        cold_ttft = cold[0].ttft_s
        warm_ttft = float(np.percentile([c.ttft_s for c in warm], 50))
        return cold_ttft / max(warm_ttft, 1e-9), hit, cold_ttft, warm_ttft

    single, _, _, _ = measure(1, "prefix")
    aff, aff_hit, cold_ttft, aff_warm = measure(2, "prefix")
    rr, rr_hit, _, rr_warm = measure(2, "round-robin")
    return {"name": f"serve_engine/router_affinity_{fmt}",
            "us_per_call": aff_warm * 1e6,
            "derived": (f"affinity_retention={aff/max(single,1e-9):.3f},"
                        f"affinity_ttft_speedup={aff:.2f}x,"
                        f"single_ttft_speedup={single:.2f}x,"
                        f"rr_ttft_speedup={rr:.2f}x,"
                        f"affinity_hit_rate={aff_hit:.3f},"
                        f"rr_hit_rate={rr_hit:.3f},"
                        f"cold_ttft_p50_ms={cold_ttft*1e3:.1f},"
                        f"affinity_warm_ttft_p50_ms={aff_warm*1e3:.1f},"
                        f"rr_warm_ttft_p50_ms={rr_warm*1e3:.1f}")}


def _router_failover_row(model, params, fmt: str):
    """Failure re-dispatch lane: 8 requests across 2 replicas, replica 0
    killed after it has streamed 6 tokens; its requests resume elsewhere
    (prompt + generated-so-far, reduced budget). ``failover_parity`` is 1
    iff every stitched token stream matches the sequential ``generate()``
    path exactly (greedy) — the router's correctness-under-failure gate."""
    import asyncio

    import jax

    from repro.serve.api import Request
    from repro.serve.engine import EngineConfig
    from repro.serve.router import Router
    from repro.serve.step import generate

    rc = ROUTER
    reqs = [Request(prompt=p, max_new_tokens=g)
            for p, g in _requests([(rc["prompt_len"], rc["gen"])] * 8,
                                  model.cfg.vocab)]
    cfg = EngineConfig(max_batch=4, prefill_chunk=16,
                       page_size=rc["page_size"],
                       max_seq_len=rc["prompt_len"] + rc["gen"])
    router = Router.build(model, params, cfg, 2, policy="least-loaded")
    router.serve(reqs)                      # warm-up: compile both replicas

    async def go():
        await router.start()
        futs = [await router.submit(r) for r in reqs]
        router.fail_replica_after(0, 6)
        comps = await asyncio.gather(*futs)
        await router.stop()
        return comps

    t0 = time.perf_counter()
    comps = asyncio.run(go())
    wall = time.perf_counter() - t0
    parity = 1
    for c, r in zip(sorted(comps, key=lambda c: c.request_id), reqs):
        ref = np.asarray(generate(model, params, r.prompt_ids[None, :],
                                  r.max_new_tokens))[0]
        if not np.array_equal(np.asarray(c.tokens), ref):
            parity = 0
    n_re = sum(c.n_redispatched for c in comps)
    tok_s = sum(c.n_generated for c in comps) / max(wall, 1e-9)
    return {"name": f"serve_engine/router_failover_{fmt}",
            "us_per_call": 1e6 / max(tok_s, 1e-9),
            "derived": (f"failover_parity={parity},"
                        f"n_redispatched={n_re},"
                        f"router_tok_s={tok_s:.1f}")}


def run():
    import jax

    from repro.models.model_zoo import build
    from repro.sparse.compress import (CompressionPlan, compress_params,
                                       prune_blocks_for_plan,
                                       quantize_compressed)

    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.5)
    pruned = prune_blocks_for_plan(params, plan, 0.85)
    cp = compress_params(pruned, plan)
    formats = {"dense": pruned, "bcsr": cp,
               "palette8": quantize_compressed(cp, bits=8)}
    # dense only on one mix (it is the reference point, not the product)
    cells = [(mix, fmt) for mix in MIXES for fmt in ("bcsr", "palette8")]
    cells.append(("mixed_len", "dense"))

    rows = []
    for mix_name, fmt in cells:
        requests = _requests(MIXES[mix_name], model.cfg.vocab)
        p = formats[fmt]
        s, eng = _engine_stats(model, p, requests)
        seq_tok_s = _sequential_tok_s(model, p, requests)
        rows.append(_row(f"serve_engine/{mix_name}_{fmt}", s, seq_tok_s, eng))

    # request-layer lanes: prefix caching (warm vs cold TTFT on the same
    # run) and priority preemption (per-class TTFT under slot contention)
    rows.append(_shared_prefix_row(model, formats["bcsr"], "bcsr"))
    rows.append(_mixed_priority_row(model, formats["bcsr"], "bcsr"))

    # router lanes (serve/router.py): replica scaling, prefix-affinity
    # retention vs the round-robin control, failure re-dispatch parity
    rows.append(_router_scale_row(model, formats["bcsr"], "bcsr"))
    rows.append(_router_affinity_row(model, formats["bcsr"], "bcsr"))
    rows.append(_router_failover_row(model, formats["bcsr"], "bcsr"))

    # recurrent archs under the engine (slot-state pools): BCSR-compressed,
    # decode-heavy mix — the --assert-speedup gate covers these rows too
    for arch in RECURRENT_ARCHS:
        rmodel = build(arch, reduced=True)
        rplan = CompressionPlan(block=(8, 64), min_sparsity=0.3,
                                min_size=4096)
        rpruned = prune_blocks_for_plan(rmodel.init(jax.random.PRNGKey(0)),
                                        rplan, 0.75)
        rcp = compress_params(rpruned, rplan)
        requests = _requests(MIXES["decode_heavy"], rmodel.cfg.vocab)
        s, eng = _engine_stats(rmodel, rcp, requests)
        seq_tok_s = _sequential_tok_s(rmodel, rcp, requests)
        rows.append(_row(f"serve_engine/{arch}_decode_heavy_bcsr",
                         s, seq_tok_s, eng))
    return rows


def _row(name, s, seq_tok_s, eng=None):
    derived = (f"engine_tok_s={s['tok_s']:.1f},"
               f"seq_tok_s={seq_tok_s:.1f},"
               f"batch_speedup={s['tok_s']/max(seq_tok_s,1e-9):.2f}x,"
               f"ttft_p50_ms={s['ttft_p50_s']*1e3:.1f},"
               f"ttft_p95_ms={s['ttft_p95_s']*1e3:.1f},"
               f"latency_p50_ms={s['latency_p50_s']*1e3:.1f},"
               f"latency_p95_ms={s['latency_p95_s']*1e3:.1f},"
               f"n_ticks={s['n_ticks']},"
               f"n_prefill_chunks={s['n_prefill_chunks']},"
               f"kv_pool_bytes={s['kv_page_bytes']},"
               f"state_pool_bytes={s['state_slot_bytes']}")
    row = {"name": name, "us_per_call": 1e6 / max(s["tok_s"], 1e-9)}
    if eng is not None:
        # registry-derived fields (whole engine lifetime: warm + timed
        # runs) + the full snapshot as row evidence
        occ = eng.metrics.get("repro_engine_page_occupancy")
        p95 = occ.percentile(95) if occ is not None else None
        derived += (f",page_occ_p95={-1.0 if p95 is None else p95:.1f}"
                    f",n_preemptions={eng.scheduler.n_preemptions}")
        row["metrics"] = eng.metrics.snapshot()
    row["derived"] = derived
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the rows to this path (BENCH json schema; "
                         "CI uploads it alongside BENCH_pr.json)")
    ap.add_argument("--assert-speedup", action="store_true",
                    help="exit nonzero unless the batched compressed engine "
                         "beats sequential compressed serving (aggregate "
                         "tokens/s) on every decode-dominated compressed "
                         "cell — attention AND recurrent (rwkv/"
                         "recurrentgemma) rows (prefill_heavy is reported "
                         "but not gated: a one-shot sequential prefill is "
                         "a single big dispatch and legitimately wins on "
                         "CPU)")
    ap.add_argument("--assert-from", default="",
                    help="apply --assert-speedup to rows loaded from this "
                         "previously written --json file instead of "
                         "re-running the benchmark — lets CI upload the "
                         "artifact first and gate afterwards, so a failed "
                         "gate still leaves the numbers to diagnose")
    args = ap.parse_args(argv)
    if args.assert_from:
        with open(args.assert_from) as f:
            rows = json.load(f)["rows"]
        args.assert_speedup = True
    else:
        rows = run()
        for r in rows:
            print(r)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"rows": rows}, f, indent=1)
            print(f"wrote {args.json}")
    if args.assert_speedup:
        import re

        bad = [r["name"] for r in rows
               if "dense" not in r["name"]
               and "prefill_heavy" not in r["name"]
               and "batch_speedup=" in r["derived"]
               and float(re.search(r"batch_speedup=([0-9.]+)x",
                                   r["derived"]).group(1)) <= 1.0]
        # shared-prefix lane: cache hits must actually happen AND cut TTFT
        for r in rows:
            if "prefix_ttft_speedup=" not in r["derived"]:
                continue
            spd = float(re.search(r"prefix_ttft_speedup=([0-9.]+)x",
                                  r["derived"]).group(1))
            hit = float(re.search(r"prefix_hit_rate=([0-9.]+)",
                                  r["derived"]).group(1))
            if spd <= 1.0 or hit <= 0.0:
                bad.append(f"{r['name']} (ttft speedup {spd}x, "
                           f"hit rate {hit})")
        for r in rows:
            d = r["derived"]
            # router scaling: 2 replicas must reach 1.6x aggregate tok/s on
            # a machine with cores to scale into (>= 8 — XLA's own intra-op
            # threads already eat part of a small core count); on 4-7 cores
            # the second replica must at least win (> 1.0), and below that
            # replicas are threads time-slicing one core, so only gate
            # against pathological overhead
            if "router_scale=" in d:
                scale = float(re.search(r"router_scale=([0-9.]+)x",
                                        d).group(1))
                n_cpus = int(re.search(r"n_cpus=(\d+)", d).group(1))
                floor = 1.6 if n_cpus >= 8 else (1.0 if n_cpus >= 4
                                                 else 0.5)
                if scale < floor:
                    bad.append(f"{r['name']} (scale {scale}x < {floor}x "
                               f"floor at {n_cpus} cpus)")
            # affinity routing must keep >= 80% of the single-replica warm-
            # TTFT speedup, and the round-robin control must show the gap
            # it would cost (sprayed warm wave -> cold prefills)
            if "affinity_retention=" in d:
                ret = float(re.search(r"affinity_retention=([0-9.]+)",
                                      d).group(1))
                ah = float(re.search(r"affinity_hit_rate=([0-9.]+)",
                                     d).group(1))
                rh = float(re.search(r"rr_hit_rate=([0-9.]+)", d).group(1))
                if ret < 0.8 or ah <= rh:
                    bad.append(f"{r['name']} (retention {ret}, hit rates "
                               f"affinity {ah} vs round-robin {rh})")
            # forced replica failure: >= 1 request re-dispatched and every
            # stitched token stream still matches generate()
            if "failover_parity=" in d:
                parity = int(re.search(r"failover_parity=(\d+)",
                                       d).group(1))
                n_re = int(re.search(r"n_redispatched=(\d+)", d).group(1))
                if parity != 1 or n_re < 1:
                    bad.append(f"{r['name']} (parity {parity}, "
                               f"{n_re} re-dispatched)")
        if bad:
            print(f"FAIL: batched engine did not beat sequential serving "
                  f"(or the prefix cache did not cut TTFT) on {bad}")
            return 1
        print("batched compressed engine > sequential on every "
              "decode-dominated compressed cell; prefix-cache hits cut "
              "warm TTFT below cold prefill; router lanes hold (replica "
              "scaling, affinity retention, failover parity)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
