"""Paper Table 2 + Fig. 8: SpC vs the state-of-the-art MM (method of
multipliers). Three paper claims validated:
  1. comparable final (accuracy, compression),
  2. SpC reaches top compression much FASTER (compression-vs-step curve),
  3. MM needs ~2x optimizer memory and a pretrained model.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (data_for, evaluate_cnn, make_cnn_step,
                               train_cnn, Timer)
from repro.core import metrics as metrics_lib
from repro.core import mm
from repro.core.optimizers import prox_adam
from repro.data.synthetic import image_batch
from repro.models.cnn import CNN_ZOO
from repro.train.losses import softmax_xent

STEPS = 300


def run(steps: int = STEPS):
    model = CNN_ZOO["lenet5"]
    data_cfg = data_for(model)
    rows = []

    # --- SpC from random init -----------------------------------------------
    t = Timer()
    opt = prox_adam(1e-3, lam=1.0)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = make_cnn_step(model, opt)
    spc_curve = []
    for s in range(steps):
        b = image_batch(data_cfg, s)
        params, opt_state, _ = step(params, opt_state, b)
        if (s + 1) % (steps // 6) == 0:
            spc_curve.append(round(metrics_lib.compression_rate(params), 3))
    spc_us = t.us(steps)
    acc_spc = evaluate_cnn(model, params, data_cfg)
    comp_spc = metrics_lib.compression_rate(params)
    st_prox = opt.init(params)
    prox_bytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves((st_prox.m, st_prox.v)))
    rows.append({"name": "mm_comparison/spc",
                 "us_per_call": spc_us,
                 "derived": (f"acc={acc_spc:.4f},comp={comp_spc:.4f},"
                             f"state_mb={prox_bytes/2**20:.2f},"
                             f"curve={'|'.join(map(str, spc_curve))}")})

    # --- MM from a pretrained model (as the paper allows it) ----------------
    pre_params, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), steps // 2)
    # calibrated on the harder (noise=1.0) synthetic task; the paper's
    # own observation holds: MM is *sensitive* to (mu0, growth) — alpha
    # 0.02 at this mu ramp collapses accuracy to 0.63 (see EXPERIMENTS.md)
    cfg = mm.MMConfig(alpha=1e-2, mu0=0.3, mu_growth=1.2,
                      mu_every=30, c_step_every=30,
                      learning_rate=2e-3, sgd_momentum=0.9)
    state = mm.mm_init(pre_params, cfg)
    mm_params = pre_params

    def loss_fn(p, b):
        return softmax_xent(model.apply(p, b["inputs"]), b["labels"])

    @jax.jit
    def mm_step(p, s, b):
        g = jax.grad(loss_fn)(p, b)
        return mm.mm_update(g, s, p, cfg)

    t = Timer()
    mm_curve = []
    for s in range(steps):
        b = image_batch(data_cfg, s)
        mm_params, state = mm_step(mm_params, state, b)
        if (s + 1) % (steps // 6) == 0:
            final = mm.mm_final_params(mm_params, state)
            mm_curve.append(round(metrics_lib.compression_rate(final), 3))
    mm_us = t.us(steps)
    final = mm.mm_final_params(mm_params, state)
    acc_mm = evaluate_cnn(model, final, data_cfg)
    comp_mm = metrics_lib.compression_rate(final)
    mm_bytes = mm.mm_state_bytes(state)
    rows.append({"name": "mm_comparison/mm",
                 "us_per_call": mm_us,
                 "derived": (f"acc={acc_mm:.4f},comp={comp_mm:.4f},"
                             f"state_mb={mm_bytes/2**20:.2f},"
                             f"pretrained=required,"
                             f"curve={'|'.join(map(str, mm_curve))}")})
    rows.append({"name": "mm_comparison/memory_ratio",
                 "us_per_call": 0.0,
                 "derived": f"mm_over_prox={mm_bytes/prox_bytes:.2f}x"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
