"""Shared benchmark utilities: small-scale CNN training harness reproducing
the paper's experimental loop (train -> compress -> optional retrain) on the
synthetic MNIST/CIFAR stand-ins (CPU container; step counts reduced, see
EXPERIMENTS.md for the full-scale mapping)."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.core import metrics as metrics_lib
from repro.core.optimizers import ProxOptimizer
from repro.data.synthetic import (CIFAR_LIKE, MNIST_LIKE, ImageStreamConfig,
                                  image_batch)
from repro.models.cnn import CNN_ZOO, CNNModel
from repro.train.losses import accuracy, softmax_xent


def data_for(model: CNNModel, batch: int = 64,
             noise: float = 1.0) -> ImageStreamConfig:
    """noise=1.0 keeps the synthetic task non-trivial (reference accuracy
    < 1.0) so the accuracy-vs-compression frontier is informative."""
    import dataclasses
    base = MNIST_LIKE if model.input_shape[-1] == 1 else CIFAR_LIKE
    return dataclasses.replace(base, batch=batch, noise=noise)


def make_cnn_step(model: CNNModel, opt: ProxOptimizer):
    def loss_fn(params, batch):
        logits = model.apply(params, batch["inputs"])
        return softmax_xent(logits, batch["labels"])

    @jax.jit
    def step(params, opt_state, batch, mask=None):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, mask=mask)
        return params, opt_state, loss

    return step


def evaluate_cnn(model: CNNModel, params, data_cfg, n_batches: int = 10,
                 seed_offset: int = 10_000) -> float:
    accs = []
    apply = jax.jit(model.apply)
    for i in range(n_batches):
        b = image_batch(data_cfg, seed_offset + i)
        accs.append(float(accuracy(apply(params, b["inputs"]), b["labels"])))
    return float(np.mean(accs))


def train_cnn(model: CNNModel, opt: ProxOptimizer, steps: int,
              seed: int = 0, params=None, mask=None, batch: int = 64,
              eval_every: Optional[int] = None):
    """Returns (params, history[(step, loss, acc?, comp)])."""
    data_cfg = data_for(model, batch)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = make_cnn_step(model, opt)
    history = []
    for s in range(steps):
        b = image_batch(data_cfg, s + seed * 100_000)
        params, opt_state, loss = step_fn(params, opt_state, b, mask)
        if eval_every and (s + 1) % eval_every == 0:
            acc = evaluate_cnn(model, params, data_cfg, n_batches=5)
            comp = metrics_lib.compression_rate(params)
            history.append({"step": s + 1, "loss": float(loss),
                            "acc": acc, "compression": comp})
    return params, history


def spc_with_retrain(model: CNNModel, lam: float, steps: int,
                     retrain_steps: int, lr: float = 1e-3, seed: int = 0,
                     optimizer: str = "prox_adam", batch: int = 64):
    """Paper pipeline on a CNN: SpC -> (mask freeze) -> debias retrain."""
    from repro.core.optimizers import get_optimizer
    opt = get_optimizer(optimizer, learning_rate=lr, lam=lam)
    params, _ = train_cnn(model, opt, steps, seed=seed, batch=batch)
    out = {"spc_params": params,
           "spc_compression": metrics_lib.compression_rate(params)}
    if retrain_steps:
        mask = masks_lib.zero_mask(params)
        opt_db = get_optimizer(optimizer, learning_rate=lr, lam=0.0)
        params2, _ = train_cnn(model, opt_db, retrain_steps, seed=seed,
                               params=params, mask=mask, batch=batch)
        out["retrain_params"] = params2
        out["retrain_compression"] = metrics_lib.compression_rate(params2)
    return out


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, calls: int = 1) -> float:
        return (time.perf_counter() - self.t0) * 1e6 / calls
