"""Paper Fig. 7 + Table 1 (retrain rows): the effect of debias retraining.

SpC vs SpC(Retrain) and Pru vs Pru(Retrain) at matched compression: the
paper's claims are (i) Pru NEEDS retraining, (ii) SpC(Retrain) reaches the
highest compression at reference-level accuracy.
"""
from __future__ import annotations

from benchmarks.common import (data_for, evaluate_cnn, spc_with_retrain,
                               train_cnn, Timer)
from repro.core import masks as masks_lib
from repro.core import metrics as metrics_lib
from repro.core import pruning
from repro.core.optimizers import prox_adam
from repro.models.cnn import CNN_ZOO

STEPS = 250
RETRAIN = 120


def run(steps: int = STEPS, retrain: int = RETRAIN):
    model = CNN_ZOO["lenet5"]
    data_cfg = data_for(model)
    rows = []

    # SpC at a high-compression lambda, with and without retraining
    t = Timer()
    out = spc_with_retrain(model, lam=1.25, steps=steps,
                           retrain_steps=retrain)
    acc_spc = evaluate_cnn(model, out["spc_params"], data_cfg)
    acc_rt = evaluate_cnn(model, out["retrain_params"], data_cfg)
    rows.append({"name": "retraining/spc",
                 "us_per_call": t.us(steps + retrain),
                 "derived": f"acc={acc_spc:.4f},comp={out['spc_compression']:.4f}"})
    rows.append({"name": "retraining/spc_retrain",
                 "us_per_call": t.us(steps + retrain),
                 "derived": f"acc={acc_rt:.4f},comp={out['retrain_compression']:.4f}"})

    # Pru at matched compression, with and without retraining
    ref_params, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), steps)
    target = out["spc_compression"]
    pruned = pruning.magnitude_prune_global(ref_params, target)
    acc_pru = evaluate_cnn(model, pruned, data_cfg)
    rows.append({"name": "retraining/pru",
                 "us_per_call": 0.0,
                 "derived": f"acc={acc_pru:.4f},comp="
                            f"{metrics_lib.compression_rate(pruned):.4f}"})

    mask = masks_lib.zero_mask(pruned)
    retrained, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), retrain,
                             params=pruned, mask=mask)
    acc_pru_rt = evaluate_cnn(model, retrained, data_cfg)
    rows.append({"name": "retraining/pru_retrain",
                 "us_per_call": 0.0,
                 "derived": f"acc={acc_pru_rt:.4f},comp="
                            f"{metrics_lib.compression_rate(retrained):.4f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
