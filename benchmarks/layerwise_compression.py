"""Paper Tables A1-A4: layer-wise compression of SpC / SpC(Retrain).

Reproduces the qualitative structure the paper reports: middle (fc/large)
layers compress far more than layers near input/output.
"""
from __future__ import annotations

from benchmarks.common import spc_with_retrain, Timer
from repro.core import metrics as metrics_lib
from repro.models.cnn import CNN_ZOO

STEPS = 250


def run(steps: int = STEPS):
    model = CNN_ZOO["lenet5"]
    t = Timer()
    out = spc_with_retrain(model, lam=1.0, steps=steps, retrain_steps=100)
    us = t.us(steps + 100)
    rows = []
    for tag, params in [("spc", out["spc_params"]),
                        ("retrain", out["retrain_params"])]:
        table = metrics_lib.layer_compression(params)
        for layer, v in table.items():
            clean = layer.replace("['", "").replace("']", ".").rstrip(".")
            rows.append({
                "name": f"layerwise/{tag}/{clean}",
                "us_per_call": us,
                "derived": (f"nnz={v['nnz']},total={v['total']},"
                            f"rate={v['compression_rate']:.4f}"),
            })
    # structural check: fc1 (largest) compresses more than conv1 (input)
    spc_table = metrics_lib.layer_compression(out["spc_params"])
    conv1 = [v for k, v in spc_table.items() if "conv1" in k][0]
    fc1 = [v for k, v in spc_table.items() if "fc1" in k][0]
    rows.append({"name": "layerwise/structure_check",
                 "us_per_call": 0.0,
                 "derived": (f"fc1_rate={fc1['compression_rate']:.3f}>"
                             f"conv1_rate={conv1['compression_rate']:.3f}="
                             f"{fc1['compression_rate'] > conv1['compression_rate']}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
