"""Paper Table 3: inference speedup + model-size reduction from compressed
weights.

Three measurements:
  1. model size: dense vs CSR-compressed bytes (the paper's 5.0MB -> 148KB),
  2. CPU wall-time: dense matmul vs CSR SpMM at the trained sparsity (the
     embedded-CPU proxy for the paper's Mali-T860 numbers),
  3. derived TPU roofline: HBM bytes moved by the dense vs BCSR Pallas
     kernel per forward (the quantity that sets memory-bound inference time
     on the target hardware).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import spc_with_retrain, Timer
from repro.core.metrics import model_size_bytes
from repro.models.cnn import CNN_ZOO
from repro.roofline.analysis import HBM_BW
from repro.sparse.formats import dense_to_bcsr, dense_to_csr

STEPS = 250


def _csr_matvec_time(w_csr, x, iters=50):
    """numpy CSR SpMM (row-segment reduction) — embedded-CPU style.

    np.add.reduceat quirk: an empty segment [i, i) returns gathered[i]
    instead of 0, so empty rows are zeroed afterwards (and trailing
    indices clamped into range)."""
    data = np.asarray(w_csr.data)
    indices = np.asarray(w_csr.indices)
    indptr = np.asarray(w_csr.indptr)
    assert len(data), "empty CSR (all weights pruned) — lambda too high"
    starts = np.minimum(indptr[:-1], len(data) - 1)
    empty = (indptr[1:] - indptr[:-1]) == 0
    t0 = time.perf_counter()
    for _ in range(iters):
        gathered = x[:, indices] * data          # (B, nnz)
        out = np.add.reduceat(gathered, starts, axis=1)
        out[:, empty] = 0.0
    return (time.perf_counter() - t0) / iters, out


def run(steps: int = STEPS):
    model = CNN_ZOO["lenet5"]
    out = spc_with_retrain(model, lam=1.0, steps=steps, retrain_steps=80)
    params = out["retrain_params"]
    rows = []

    dense_b = model_size_bytes(params, sparse=False)
    sparse_b = model_size_bytes(params, sparse=True)

    # beyond-paper: deep-compression stage (k-means palette + Huffman)
    from benchmarks.common import data_for, evaluate_cnn
    from repro.core.quantize import quantize_tree, quantized_size_bytes
    qparams, qreport = quantize_tree(params, bits=4)
    dc_b = quantized_size_bytes(qparams, bits=4, reports=qreport)
    acc = evaluate_cnn(model, params, data_for(model), n_batches=5)
    qacc = evaluate_cnn(model, qparams, data_for(model), n_batches=5)
    rows.append({"name": "inference_speedup/deep_compression_stage",
                 "us_per_call": 0.0,
                 "derived": (f"csr_kb={sparse_b/1024:.0f},"
                             f"quant4_kb={dc_b/1024:.0f},"
                             f"total_ratio={dense_b/dc_b:.0f}x,"
                             f"acc={acc:.4f},quant_acc={qacc:.4f}")})

    rows.append({"name": "inference_speedup/model_size",
                 "us_per_call": 0.0,
                 "derived": (f"dense_kb={dense_b/1024:.0f},"
                             f"csr_kb={sparse_b/1024:.0f},"
                             f"ratio={dense_b/sparse_b:.1f}x")})

    # fc1 is the dominant layer (400k of 430k weights) — time it
    w = np.asarray(params["fc1"]["w"]).T           # (500, 800)
    x = np.random.default_rng(0).normal(size=(64, 800)).astype(np.float32)
    iters = 50
    t0 = time.perf_counter()
    for _ in range(iters):
        y_dense = x @ w.T
    dense_t = (time.perf_counter() - t0) / iters

    csr = dense_to_csr(w)
    sparse_t, y_sparse = _csr_matvec_time(csr, x, iters)
    np.testing.assert_allclose(y_sparse[:, :w.shape[0]].sum(), y_dense.sum(),
                               rtol=1e-2)
    rows.append({"name": "inference_speedup/fc1_cpu_time",
                 "us_per_call": sparse_t * 1e6,
                 "derived": (f"dense_us={dense_t*1e6:.1f},"
                             f"sparse_us={sparse_t*1e6:.1f},"
                             f"speedup={dense_t/sparse_t:.2f}x,"
                             f"nnz_frac={csr.nnz/w.size:.4f}")})

    # derived TPU memory-bound time: HBM bytes for dense vs BCSR weights
    bcsr = dense_to_bcsr(w, block=(8, 128))
    dense_bytes = w.size * 4 + x.size * 4
    bcsr_bytes = bcsr.nbytes + x.size * 4
    rows.append({"name": "inference_speedup/tpu_roofline_derived",
                 "us_per_call": bcsr_bytes / HBM_BW * 1e6,
                 "derived": (f"dense_hbm_us={dense_bytes/HBM_BW*1e6:.3f},"
                             f"bcsr_hbm_us={bcsr_bytes/HBM_BW*1e6:.3f},"
                             f"block_density={bcsr.n_blocks/(max(1,(np.prod(bcsr.block_grid)))):.3f}")})

    rows.append(decode_compressed_row())
    return rows


def decode_compressed_row(gen_steps: int = 8):
    """Whole-model dense vs BCSR vs PaletteBCSR decode through the serving
    runtime: the transformer decode loop running on ``CompressedParams``
    (BCSR attention/MLP projections), its 8-bit palette-quantized form
    (Deep Compression stage 2), and the same pruned weights served dense —
    real serving bytes and tokens/s for all three."""
    import jax

    from repro.models.model_zoo import build
    from repro.serve.step import generate
    from repro.sparse.compress import (CompressionPlan, compress_params,
                                       compressed_size_bytes,
                                       prune_blocks_for_plan,
                                       quantize_compressed)

    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.5)
    pruned = prune_blocks_for_plan(params, plan, 0.85)
    cp = compress_params(pruned, plan)
    qcp = quantize_compressed(cp, bits=8)
    dense_b = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(pruned))
    comp_b = compressed_size_bytes(cp)
    pal_b = compressed_size_bytes(qcp)

    import jax.numpy as jnp

    from repro.serve.step import make_decode_step

    prompt = jnp.zeros((4, 8), jnp.int32)
    # jit once outside the loop: generate() builds fresh jit wrappers per
    # call, so timing it would measure trace+compile, not decode
    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_decode_step(model))

    def run_once(p):
        cache = model.init_cache(prompt.shape[0], prompt.shape[1] + gen_steps)
        logits, cache = prefill(p, prompt, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(prompt.shape[1], prompt.shape[1] + gen_steps - 1):
            tok, _, cache = decode(p, tok[:, None], cache, jnp.int32(t))
        return tok

    def timed(p):
        jax.block_until_ready(run_once(p))             # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(run_once(p))
        return time.perf_counter() - t0

    dense_t, comp_t, pal_t = timed(pruned), timed(cp), timed(qcp)
    n_tok = prompt.shape[0] * gen_steps
    return {"name": "inference_speedup/decode_dense_vs_compressed",
            "us_per_call": comp_t / n_tok * 1e6,
            "derived": (f"dense_us_tok={dense_t/n_tok*1e6:.1f},"
                        f"compressed_us_tok={comp_t/n_tok*1e6:.1f},"
                        f"palette8_us_tok={pal_t/n_tok*1e6:.1f},"
                        f"dense_tok_s={n_tok/dense_t:.1f},"
                        f"bcsr_tok_s={n_tok/comp_t:.1f},"
                        f"palette8_tok_s={n_tok/pal_t:.1f},"
                        f"dense_kb={dense_b/1024:.0f},"
                        f"bcsr_kb={comp_b/1024:.0f},"
                        f"palette8_kb={pal_b/1024:.0f},"
                        f"size_ratio={dense_b/comp_b:.2f}x,"
                        f"palette_ratio={dense_b/pal_b:.2f}x")}


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=STEPS,
                    help="SpC training steps (CI tier-2 uses a short run)")
    ap.add_argument("--json", default="",
                    help="also write the result rows to this JSON path — "
                         "CI uploads it as the BENCH_pr.json artifact and "
                         "benchmarks/check_regression.py gates the "
                         "compressed-decode tokens/s against the committed "
                         "benchmarks/BENCH_baseline.json")
    args = ap.parse_args()
    rows = run(steps=args.steps)
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"steps": args.steps, "rows": rows}, f, indent=1)
        print(f"wrote {args.json}")
