"""Tier-2 bench regression gate: compressed-decode tokens/s vs baseline.

CI runs ``benchmarks.inference_speedup --json BENCH_pr.json`` on every run,
uploads the JSON as an artifact, and then runs this script: the build FAILS
if the whole-model compressed (BCSR) decode throughput regressed more than
``--max-regress`` (default 20%) against the committed
``benchmarks/BENCH_baseline.json``.

Absolute tokens/s are machine-dependent (the committed baseline was not
necessarily produced on the same runner class), so the default gate is
**machine-corrected**: it compares the compressed-decode throughput
normalized by the *same run's* dense-decode throughput
(``bcsr_tok_s / dense_tok_s``) against the baseline's normalized value. A
slower/noisier runner slows dense and compressed alike and cancels out; a
real compressed-path regression (kernel dispatch, extra copies, a lost
fusion) shows up as the ratio dropping. Pass ``--absolute`` to gate on raw
tokens/s instead — only meaningful when baseline and run share a machine
class. After a legitimate perf change, regenerate the baseline:

    PYTHONPATH=src python -m benchmarks.inference_speedup --steps 60 \
        --json /tmp/BENCH_pr.json
    python -m benchmarks.check_regression /tmp/BENCH_pr.json --update

and commit the result.
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

BASELINE = "benchmarks/BENCH_baseline.json"
DECODE_ROW = "inference_speedup/decode_dense_vs_compressed"


def _field(derived: str, name: str, required: bool = True):
    m = re.search(rf"{name}=([0-9.]+)", derived)
    if not m:
        if required:
            raise SystemExit(f"no {name} in {derived!r}")
        return None
    return float(m.group(1))


def decode_stats(report: dict, required: bool = True):
    """(bcsr_tok_s, dense_tok_s) from a bench JSON report.

    ``required=False`` (the baseline side) returns None instead of failing
    when the row or a metric key is absent — a metric that exists in the PR
    report but not yet in the committed baseline is skipped with a warning,
    not a crash, so adding new bench metrics doesn't break the gate on
    their first run (re-baseline with --update to start gating them)."""
    for row in report["rows"]:
        if row["name"] == DECODE_ROW:
            bcsr = _field(row["derived"], "bcsr_tok_s", required)
            dense = _field(row["derived"], "dense_tok_s", required)
            if bcsr is None or dense is None:
                return None
            return (bcsr, dense)
    if required:
        raise SystemExit(f"row {DECODE_ROW!r} missing from report")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="BENCH_pr.json from inference_speedup "
                                   "--json")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="fail if compressed-decode throughput drops more "
                         "than this fraction below the baseline")
    ap.add_argument("--absolute", action="store_true",
                    help="gate on raw tokens/s instead of the machine-"
                         "corrected (bcsr/dense) ratio — requires baseline "
                         "and run to share a machine class")
    ap.add_argument("--update", action="store_true",
                    help="copy the report over the baseline instead of "
                         "gating (commit the result)")
    args = ap.parse_args(argv)

    if args.update:
        shutil.copy(args.report, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0

    with open(args.report) as f:
        pr_bcsr, pr_dense = decode_stats(json.load(f))
    with open(args.baseline) as f:
        base = decode_stats(json.load(f), required=False)
    if base is None:
        print(f"WARNING: {DECODE_ROW!r} metrics present in {args.report} "
              f"but missing from baseline {args.baseline} — skipping the "
              "gate for this metric (run with --update and commit the "
              "result to start gating it)")
        return 0
    base_bcsr, base_dense = base

    if args.absolute:
        metric, base_metric, unit = pr_bcsr, base_bcsr, "tok/s"
    else:
        metric = pr_bcsr / max(pr_dense, 1e-9)
        base_metric = base_bcsr / max(base_dense, 1e-9)
        unit = "x dense"
    floor = base_metric * (1.0 - args.max_regress)
    verdict = "OK" if metric >= floor else "REGRESSION"
    print(f"compressed decode: {pr_bcsr:.1f} tok/s "
          f"({pr_bcsr / max(pr_dense, 1e-9):.3f}x dense) vs baseline "
          f"{base_bcsr:.1f} ({base_bcsr / max(base_dense, 1e-9):.3f}x) — "
          f"gated metric {metric:.3f} {unit}, floor {floor:.3f} "
          f"-> {verdict}")
    return 0 if metric >= floor else 1


if __name__ == "__main__":
    sys.exit(main())
