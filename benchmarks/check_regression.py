"""Tier-2 bench regression gate: serve + kernel lanes in one invocation.

CI runs ``benchmarks.inference_speedup --json BENCH_pr.json`` and
``benchmarks.kernel_bench --json BENCH_kernels.json`` on every run, uploads
the JSONs as artifacts, and then runs this script once over the (report,
baseline) pairs: the build FAILS if any gated metric regressed more than
``--max-regress`` against its committed baseline.

Gated metrics, extracted per report:

* ``inference_speedup/decode_dense_vs_compressed`` — whole-model
  compressed (BCSR) decode throughput, gated on the machine-corrected
  ``bcsr_tok_s / dense_tok_s`` ratio (``--absolute`` gates raw tok/s
  instead — only meaningful when baseline and run share a machine class),
* any row carrying ``speedup_vs_dense=`` in its derived field (the kernel
  lane) — already a same-run ratio against dense XLA, machine-corrected
  by construction,
* any row carrying ``prefix_ttft_speedup=`` (the serve-engine
  shared-prefix lane) — warm (prefix-cache-hit) vs cold prefill TTFT of
  the same run, a same-run ratio for the same reason,
* any row carrying ``router_scale=`` (the serve-engine router lane) —
  2-replica vs 1-replica aggregate tokens/s of the same run,
* any row carrying ``affinity_retention=`` — the fraction of the
  single-replica warm-TTFT speedup that prefix-affinity routing keeps at
  2 replicas (also a same-run ratio).

Absolute numbers are machine-dependent (the committed baselines were not
necessarily produced on the same runner class); ratios against the same
run's dense path cancel runner speed out, so a drop means a real
compressed-path regression (kernel dispatch, extra copies, a lost fusion).
A metric present in a PR report but missing from its baseline is skipped
with a warning, not a crash — re-baseline with ``--update`` to start
gating it. After a legitimate perf change, regenerate and commit:

    PYTHONPATH=src python -m benchmarks.inference_speedup --steps 60 \
        --json /tmp/BENCH_pr.json
    PYTHONPATH=src python -m benchmarks.kernel_bench \
        --json /tmp/BENCH_kernels.json
    python -m benchmarks.check_regression /tmp/BENCH_pr.json \
        /tmp/BENCH_kernels.json --baseline benchmarks/BENCH_baseline.json \
        benchmarks/BENCH_kernels_baseline.json --update
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

BASELINE = "benchmarks/BENCH_baseline.json"
DECODE_ROW = "inference_speedup/decode_dense_vs_compressed"


def _field(derived: str, name: str):
    m = re.search(rf"{name}=([0-9.]+)", derived)
    return float(m.group(1)) if m else None


def gated_metrics(report: dict, absolute: bool = False) -> dict:
    """name -> (gated value, display string) for every gateable row.

    The decode row gates on the machine-corrected bcsr/dense ratio (or raw
    tok/s under ``absolute``); any other row gates on its
    ``speedup_vs_dense`` derived field (already a same-run ratio). Rows
    without a gateable metric are ignored.
    """
    out = {}
    for row in report.get("rows", []):
        derived = row.get("derived", "")
        if row["name"] == DECODE_ROW:
            bcsr = _field(derived, "bcsr_tok_s")
            dense = _field(derived, "dense_tok_s")
            if bcsr is None or dense is None:
                continue
            ratio = bcsr / max(dense, 1e-9)
            if absolute:
                out[row["name"]] = (bcsr, f"{bcsr:.1f} tok/s "
                                          f"({ratio:.3f}x dense)")
            else:
                out[row["name"]] = (ratio, f"{ratio:.3f}x dense "
                                           f"({bcsr:.1f} tok/s)")
        else:
            v = _field(derived, "speedup_vs_dense")
            if v is not None:
                out[row["name"]] = (v, f"{v:.3f}x dense")
                continue
            # serve-engine shared-prefix lane: warm (cache-hit) vs cold
            # TTFT of the same run — a same-run ratio, machine-corrected
            # by construction like the kernel lane
            v = _field(derived, "prefix_ttft_speedup")
            if v is not None:
                out[row["name"]] = (v, f"{v:.3f}x cold-prefill TTFT")
                continue
            # router lanes: replica-scaling and affinity-retention are
            # same-run ratios too (2-replica vs 1-replica of the same
            # process on the same machine)
            v = _field(derived, "router_scale")
            if v is not None:
                out[row["name"]] = (v, f"{v:.3f}x 1-replica tok/s")
                continue
            v = _field(derived, "affinity_retention")
            if v is not None:
                out[row["name"]] = (v, f"{v:.3f}x single-replica "
                                       "warm-TTFT speedup")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", nargs="+",
                    help="bench JSON report(s): BENCH_pr.json from "
                         "inference_speedup --json, BENCH_kernels.json "
                         "from kernel_bench --json, ...")
    ap.add_argument("--baseline", nargs="+", default=None,
                    help="committed baseline(s), matched to the reports by "
                         f"position (default: {BASELINE})")
    ap.add_argument("--max-regress", type=float, nargs="+", default=[0.20],
                    help="fail if a gated metric drops more than this "
                         "fraction below its baseline; one value for all "
                         "pairs or one per (report, baseline) pair")
    ap.add_argument("--absolute", action="store_true",
                    help="gate the decode row on raw tokens/s instead of "
                         "the machine-corrected (bcsr/dense) ratio — "
                         "requires baseline and run to share a machine "
                         "class")
    ap.add_argument("--update", action="store_true",
                    help="copy each report over its baseline instead of "
                         "gating (commit the result)")
    args = ap.parse_args(argv)

    baselines = args.baseline or [BASELINE]
    if len(baselines) != len(args.report):
        raise SystemExit(f"{len(args.report)} report(s) but "
                         f"{len(baselines)} baseline(s) — pass one "
                         "--baseline per report, in order")
    regress = args.max_regress
    if len(regress) == 1:
        regress = regress * len(args.report)
    if len(regress) != len(args.report):
        raise SystemExit(f"{len(args.report)} report(s) but {len(regress)} "
                         "--max-regress value(s)")

    if args.update:
        for report, baseline in zip(args.report, baselines):
            shutil.copy(report, baseline)
            print(f"baseline updated: {baseline}")
        return 0

    failed = False
    for report, baseline, mr in zip(args.report, baselines, regress):
        with open(report) as f:
            pr = gated_metrics(json.load(f), args.absolute)
        if not pr:
            raise SystemExit(f"no gateable metrics in {report} — wrong "
                             "file, or every row lost its derived fields?")
        with open(baseline) as f:
            base = gated_metrics(json.load(f), args.absolute)
        for name, (value, disp) in pr.items():
            if name not in base:
                print(f"WARNING: {name!r} present in {report} but missing "
                      f"from baseline {baseline} — skipping the gate for "
                      "this metric (run with --update and commit the "
                      "result to start gating it)")
                continue
            base_value, base_disp = base[name]
            floor = base_value * (1.0 - mr)
            ok = value >= floor
            failed |= not ok
            print(f"{name}: {disp} vs baseline {base_disp} — "
                  f"floor {floor:.3f} -> {'OK' if ok else 'REGRESSION'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
