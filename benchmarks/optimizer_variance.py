"""Paper Fig. 5: Prox-ADAM vs Prox-RMSProp stability across random seeds.

The paper observes Prox-ADAM has visibly smaller variance in (accuracy,
compression) across seeds; we reproduce with N seeds on LeNet-5.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import data_for, evaluate_cnn, train_cnn, Timer
from repro.core import metrics as metrics_lib
from repro.core.optimizers import prox_adam, prox_rmsprop
from repro.models.cnn import CNN_ZOO

SEEDS = 4
STEPS = 200
LAM = 1.0


def run(steps: int = STEPS, seeds: int = SEEDS):
    model = CNN_ZOO["lenet5"]
    data_cfg = data_for(model)
    rows = []
    for name, opt_fn in [("prox_adam", prox_adam),
                         ("prox_rmsprop", prox_rmsprop)]:
        accs, comps = [], []
        t = Timer()
        for seed in range(seeds):
            params, _ = train_cnn(model, opt_fn(1e-3, lam=LAM), steps,
                                  seed=seed)
            accs.append(evaluate_cnn(model, params, data_cfg, n_batches=5))
            comps.append(metrics_lib.compression_rate(params))
        rows.append({
            "name": f"optimizer_variance/{name}",
            "us_per_call": t.us(steps * seeds),
            "derived": (f"acc_mean={np.mean(accs):.4f},"
                        f"acc_std={np.std(accs):.4f},"
                        f"comp_mean={np.mean(comps):.4f},"
                        f"comp_std={np.std(comps):.4f}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
