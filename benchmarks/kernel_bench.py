"""Compiled kernel-bench lane: the Pallas kernel suite vs dense XLA.

Times the four serving-path kernels at serving sparsities and batch shapes:

* paged attention  — fused page-gather flash-decode kernel vs the jnp
                     gather-the-whole-pool reference (decode and mixed
                     prefill/decode tick shapes), with a built-in parity
                     assert (the interpret-mode correctness smoke),
* gather_block_matmul (BCSR spmm) and the palette dequant-matmul vs a
  dense XLA matmul,
* SDDMM (masked weight gradient) vs the dense ``dy.T @ x`` product.

Off-TPU the Pallas numbers are interpret-mode (not meaningful as wall
time; the roofline-derived TPU estimates carry the expected numbers), so
the gateable ``speedup_vs_dense`` field is measured on the path serving
actually takes on this machine (``resolve_backend('auto')``): the jnp ref
kernels on CPU, the compiled Pallas kernels on TPU. It is a same-run
ratio against dense XLA, so it is machine-corrected by construction and
gated by ``benchmarks/check_regression.py`` against
``benchmarks/BENCH_kernels_baseline.json``:

    PYTHONPATH=src python -m benchmarks.kernel_bench --json BENCH_kernels.json
    python -m benchmarks.check_regression BENCH_kernels.json \
        --baseline benchmarks/BENCH_kernels_baseline.json --max-regress 0.5
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_interpret
from repro.obs.profile import Profiler
from repro.kernels.bsr_sddmm import ops as sddmm_ops
from repro.kernels.bsr_spmm import ops as spmm_ops
from repro.kernels.bsr_spmm import ref as spmm_ref
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention import ref as paged_ref
from repro.kernels.prox_adam import ops as prox_ops
from repro.roofline.analysis import HBM_BW
from repro.sparse.compress import quantize_bcsr
from repro.sparse.formats import dense_to_bcsr

PARITY_TOL = 1e-4


def _time(f, iters=3):
    f()  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _block_sparse(rng, n, k, bl, sparsity):
    w = np.zeros((n, k), np.float32)
    for i in range(n // bl[0]):
        for j in range(k // bl[1]):
            if rng.random() >= sparsity:
                w[i*bl[0]:(i+1)*bl[0], j*bl[1]:(j+1)*bl[1]] = \
                    rng.normal(size=bl)
    return w


# -- paged attention --------------------------------------------------------

def _paged_scenario(rng, b, c, ctx):
    kv, g, hd, ps = 4, 4, 64, 16
    h = kv * g
    p_log = -(-(ctx + c) // ps)
    n_pages = 1 + b * p_log
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, hd)), jnp.float32)
    table = jnp.asarray(
        1 + np.arange(b * p_log, dtype=np.int32).reshape(b, p_log))
    start = np.full(b, ctx, np.int32)
    positions = jnp.asarray(start[:, None] + np.arange(c)[None], jnp.int32)
    return q, kp, vp, table, positions, (kv, hd, ps, p_log)


def _paged_row(name, rng, b, c, ctx, iters):
    q, kp, vp, table, positions, (kv, hd, ps, p_log) = \
        _paged_scenario(rng, b, c, ctx)
    ref_fn = jax.jit(functools.partial(paged_ref.paged_attention_ref,
                                       window=None))
    ref_us = _time(lambda: ref_fn(q, kp, vp, table, positions), iters)
    pal_us = _time(lambda: paged_ops.paged_flash_attention(
        q, kp, vp, table, positions), iters)
    err = float(jnp.max(jnp.abs(
        paged_ops.paged_flash_attention(q, kp, vp, table, positions)
        - ref_fn(q, kp, vp, table, positions))))
    if err > PARITY_TOL:
        raise SystemExit(f"{name}: paged-attention kernel diverges from the "
                         f"jnp reference (max_err={err:.2e} > {PARITY_TOL})")
    # TPU roofline: the gather path reads (and writes a copy of) the whole
    # (B, P*ps) context per layer call; the paged kernel reads only the
    # pages below the causal frontier
    kv_bytes = 2 * 4 * kv * hd * ps                      # k+v, one page
    gather_b = 3 * b * p_log * kv_bytes                  # read + copy out
    live = -(-(ctx + c) // ps)
    paged_b = b * live * kv_bytes
    derived = (f"max_err={err:.1e},ref_us={ref_us:.1f},"
               f"tpu_gather_us={gather_b/HBM_BW*1e6:.3f},"
               f"tpu_paged_us={paged_b/HBM_BW*1e6:.3f}")
    if not use_interpret():                              # compiled kernel
        derived += f",speedup_vs_dense={ref_us/max(pal_us, 1e-9):.4f}"
    return {"name": name, "us_per_call": pal_us, "derived": derived}


# -- BCSR / palette spmm ----------------------------------------------------

def _spmm_row(name, rng, sparsity, iters, bits=0):
    m_rows, n, k, bl = 64, 512, 512, (8, 64)
    w = _block_sparse(rng, n, k, bl, sparsity)
    mat = dense_to_bcsr(w, bl)
    x = jnp.asarray(rng.normal(size=(m_rows, k)), jnp.float32)
    wd = jnp.asarray(w)
    dense_fn = jax.jit(lambda a: a @ wd.T)
    dense_us = _time(lambda: dense_fn(x), iters)
    if bits:
        mat = quantize_bcsr(mat, bits)
        ref_fn = jax.jit(spmm_ref.spmm_palette_fwd_ref)
        pal_us = _time(lambda: spmm_ops.spmm_palette(x, mat, bm=64), iters)
    else:
        ref_fn = jax.jit(spmm_ref.spmm_fwd_ref)
        pal_us = _time(lambda: spmm_ops.spmm(x, mat, bm=64), iters)
    ref_us = _time(lambda: ref_fn(x, mat), iters)
    serving_us = pal_us if not use_interpret() else ref_us
    density = mat.n_blocks / ((n // bl[0]) * (k // bl[1]))
    dense_b = (w.size + x.size + m_rows * n) * 4
    bcsr_b = mat.nbytes + (x.size + m_rows * n) * 4
    return {"name": name, "us_per_call": pal_us,
            "derived": (f"density={density:.2f},dense_us={dense_us:.1f},"
                        f"ref_us={ref_us:.1f},"
                        f"tpu_dense_us={dense_b/HBM_BW*1e6:.3f},"
                        f"tpu_bcsr_us={bcsr_b/HBM_BW*1e6:.3f},"
                        f"speedup_vs_dense="
                        f"{dense_us/max(serving_us, 1e-9):.4f}")}


def _sddmm_row(name, rng, sparsity, iters):
    m_rows, n, k, bl = 64, 512, 512, (8, 64)
    w = _block_sparse(rng, n, k, bl, sparsity)
    mat = dense_to_bcsr(w, bl)
    x = jnp.asarray(rng.normal(size=(m_rows, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m_rows, n)), jnp.float32)
    dense_fn = jax.jit(lambda a, b: a.T @ b)
    dense_us = _time(lambda: dense_fn(dy, x), iters)
    pal_us = _time(lambda: sddmm_ops.bsr_weight_grad(x, dy, mat, bm=64),
                   iters)
    # parity smoke: the kernel (the path every backend's dw takes; see
    # sparse/ops.py) vs the eager per-slot reference, one shot
    err = float(jnp.max(jnp.abs(
        sddmm_ops.bsr_weight_grad(x, dy, mat, bm=64)
        - sddmm_ops.bsr_weight_grad_ref(x, dy, mat))))
    if err > PARITY_TOL:
        raise SystemExit(f"{name}: SDDMM kernel diverges from reference "
                         f"(max_err={err:.2e} > {PARITY_TOL})")
    dense_b = (w.size + x.size + dy.size) * 4
    bcsr_b = mat.data.size * 4 + (x.size + dy.size) * 4
    return {"name": name, "us_per_call": pal_us,
            "derived": (f"max_err={err:.1e},dense_us={dense_us:.1f},"
                        f"tpu_dense_us={dense_b/HBM_BW*1e6:.3f},"
                        f"tpu_sddmm_us={bcsr_b/HBM_BW*1e6:.3f},"
                        f"speedup_vs_dense="
                        f"{dense_us/max(pal_us, 1e-9):.4f}")}


def run(iters: int = 3):
    # the obs kernel_call hooks see every public kernel entry the bench
    # exercises — the profiler summary rides along as its own BENCH row
    with Profiler() as prof:
        rows = _run_rows(iters)
    summary = prof.summary()
    if summary:
        total_ms = sum(r["total_ms"] for r in summary.values())
        derived = ",".join(
            f"{name.replace('/', '_')}_ms={r['total_ms']:.1f}"
            for name, r in sorted(summary.items()))
        rows.append({"name": "kernel/profile_hooks",
                     "us_per_call": total_ms * 1e3,
                     "derived": derived, "profile": summary})
    return rows


def _run_rows(iters: int):
    rows = []
    rng = np.random.default_rng(0)

    # paged attention: a pure-decode tick and a mixed prefill tick at the
    # engine's default-ish shapes (B slots x C new tokens, ctx tokens deep)
    rows.append(_paged_row("kernel/paged_attention_decode", rng,
                           b=4, c=1, ctx=96, iters=iters))
    rows.append(_paged_row("kernel/paged_attention_mixed_prefill", rng,
                           b=4, c=32, ctx=64, iters=iters))

    # BCSR spmm + palette dequant-matmul at serving sparsities
    rows.append(_spmm_row("kernel/bsr_spmm_s85", rng, 0.85, iters))
    rows.append(_spmm_row("kernel/bsr_spmm_s95", rng, 0.95, iters))
    rows.append(_spmm_row("kernel/palette8_spmm_s90", rng, 0.90, iters,
                          bits=8))

    # SDDMM masked weight gradient vs dense dy.T @ x
    rows.append(_sddmm_row("kernel/sddmm_dw_s90", rng, 0.90, iters))

    # fused prox-adam: 1 HBM pass per tensor vs ~7 unfused
    shape = (1024, 512)
    wt = jnp.asarray(rng.normal(size=shape), jnp.float32)
    g = jnp.asarray(rng.normal(size=shape), jnp.float32)
    mm_ = jnp.zeros(shape, jnp.float32)
    v = jnp.zeros(shape, jnp.float32)
    sc = prox_ops.make_scalars(1e-3, 1.0, 0.9, 0.999, 1e-8, 1)
    us = _time(lambda: prox_ops.fused_update_leaf(wt, g, mm_, v, sc), iters)
    nbytes = wt.nbytes
    fused = 7 * nbytes        # r/w of w,m,v + read g
    unfused = 16 * nbytes     # each sub-op round-trips HBM
    rows.append({"name": "kernel/fused_prox_adam_interp",
                 "us_per_call": us,
                 "derived": (f"tpu_fused_us={fused/HBM_BW*1e6:.3f},"
                             f"tpu_unfused_us={unfused/HBM_BW*1e6:.3f},"
                             f"fusion_win={unfused/fused:.2f}x")})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write rows to this path")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    rows = run(iters=args.iters)
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
