"""Pallas kernel benchmarks: per-call timing (interpret mode on CPU — the
derived column carries the TPU-roofline estimate that matters) + the fused
prox-adam HBM-pass arithmetic from DESIGN.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm import ops as spmm_ops
from repro.kernels.prox_adam import ops as prox_ops
from repro.roofline.analysis import HBM_BW
from repro.sparse.formats import dense_to_bcsr


def _time(f, iters=3):
    f()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # BCSR spmm at paper-like sparsity (90% of blocks zero)
    n, k, bl = 256, 256, (32, 32)
    w = np.zeros((n, k), np.float32)
    for i in range(n // bl[0]):
        for j in range(k // bl[1]):
            if rng.random() < 0.1:
                w[i*bl[0]:(i+1)*bl[0], j*bl[1]:(j+1)*bl[1]] = rng.normal(
                    size=bl)
    m = dense_to_bcsr(w, bl)
    x = jnp.asarray(rng.normal(size=(64, k)), jnp.float32)
    us = _time(lambda: spmm_ops.spmm(x, m, bm=32))
    dense_bytes = (w.size + x.size + 64 * n) * 4
    bcsr_bytes = m.nbytes + (x.size + 64 * n) * 4
    rows.append({"name": "kernel/bsr_spmm_interp",
                 "us_per_call": us,
                 "derived": (f"density={m.n_blocks/64:.2f},"
                             f"tpu_dense_us={dense_bytes/HBM_BW*1e6:.3f},"
                             f"tpu_bcsr_us={bcsr_bytes/HBM_BW*1e6:.3f}")})

    # fused prox-adam: 1 HBM pass per tensor vs ~7 unfused
    shape = (1024, 512)
    wt = jnp.asarray(rng.normal(size=shape), jnp.float32)
    g = jnp.asarray(rng.normal(size=shape), jnp.float32)
    mm_ = jnp.zeros(shape, jnp.float32)
    v = jnp.zeros(shape, jnp.float32)
    sc = prox_ops.make_scalars(1e-3, 1.0, 0.9, 0.999, 1e-8, 1)
    us = _time(lambda: prox_ops.fused_update_leaf(wt, g, mm_, v, sc))
    nbytes = wt.nbytes
    fused = 7 * nbytes        # r/w of w,m,v + read g
    unfused = 16 * nbytes     # each sub-op round-trips HBM
    rows.append({"name": "kernel/fused_prox_adam_interp",
                 "us_per_call": us,
                 "derived": (f"tpu_fused_us={fused/HBM_BW*1e6:.3f},"
                             f"tpu_unfused_us={unfused/HBM_BW*1e6:.3f},"
                             f"fusion_win={unfused/fused:.2f}x")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
