"""Paper Fig. 6 + Table 1: compression rate vs test accuracy over lambda,
SpC (ours) vs Pru (magnitude pruning), on LeNet-5 / synthetic MNIST.

Validates the paper's headline: SpC holds accuracy to far higher compression
than pruning without retraining.
"""
from __future__ import annotations

import jax

from benchmarks.common import data_for, evaluate_cnn, train_cnn, Timer
from repro.core import metrics as metrics_lib
from repro.core import pruning
from repro.core.optimizers import prox_adam
from repro.models.cnn import CNN_ZOO

LAMBDAS = [0.0, 0.25, 0.5, 1.0, 1.5, 2.5]
STEPS = 250


def run(steps: int = STEPS):
    model = CNN_ZOO["lenet5"]
    data_cfg = data_for(model)
    rows = []

    # reference (no compression)
    t = Timer()
    ref_params, _ = train_cnn(model, prox_adam(1e-3, lam=0.0), steps)
    ref_acc = evaluate_cnn(model, ref_params, data_cfg)
    rows.append({"name": "compression_sweep/reference",
                 "us_per_call": t.us(steps),
                 "derived": f"acc={ref_acc:.4f}"})

    for lam in LAMBDAS[1:]:
        t = Timer()
        params, _ = train_cnn(model, prox_adam(1e-3, lam=lam), steps)
        acc = evaluate_cnn(model, params, data_cfg)
        comp = metrics_lib.compression_rate(params)
        rows.append({"name": f"compression_sweep/spc_lam{lam}",
                     "us_per_call": t.us(steps),
                     "derived": f"acc={acc:.4f},comp={comp:.4f}"})

    # Pru: threshold the reference model at increasing quality (no retrain)
    for q in [0.25, 0.5, 1.0, 2.0]:
        t = Timer()
        pruned = pruning.magnitude_prune_std(ref_params, q)
        acc = evaluate_cnn(model, pruned, data_cfg)
        comp = metrics_lib.compression_rate(pruned)
        rows.append({"name": f"compression_sweep/pru_q{q}",
                     "us_per_call": t.us(1),
                     "derived": f"acc={acc:.4f},comp={comp:.4f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
