"""Measure one cell and print roofline terms (no cache)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json, time
from repro.launch.dryrun import lower_cell
from repro.roofline import analysis
from repro.roofline.hlo_cost import module_cost

arch, shape = sys.argv[1], sys.argv[2]
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
t0 = time.time()
compiled, cfg, shp, meta = lower_cell(arch, shape, multi)
mem = compiled.memory_analysis()
roof = analysis.analyze(compiled.as_text(), cfg, shp, "multi" if multi else "single",
                        meta["chips"], compiled.cost_analysis(), mem)
d = roof.as_dict()
print(json.dumps({k: (round(v, 5) if isinstance(v, float) else v)
                  for k, v in d.items() if k != "collective_breakdown"}, indent=1))
print("collectives:", {k: f"{v:.2e}" for k, v in d["collective_breakdown"].items()})
print(f"mem/dev GB: {(mem.argument_size_in_bytes+mem.temp_size_in_bytes+mem.output_size_in_bytes-mem.alias_size_in_bytes)/2**30:.2f} (temp {mem.temp_size_in_bytes/2**30:.2f})")
print(f"compile {time.time()-t0:.0f}s  microbatches={meta.get('microbatches')}")
