import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re
from repro.launch.dryrun import lower_cell
from repro.roofline import hlo_cost as H

arch, shape = sys.argv[1], sys.argv[2]
compiled, cfg, shp, meta = lower_cell(arch, shape, False)
comps, entry = H.parse_module(compiled.as_text())

rows = []
def walk(name, mult):
    comp = comps[name]
    for op in comp.ops:
        if op.kind == "while":
            t = H._trip_count(op)
            for b in op.called:
                if b in comps and ("region" in b):
                    walk(b, mult * t)
            continue
        base = op.kind.replace("-start","")
        if base in H._COLLECTIVES:
            rows.append((op.result_bytes * mult, op.result_bytes, mult, base, op.op_name_meta[:110]))
walk(entry, 1)
rows.sort(key=lambda r: -r[0])
tot = sum(r[0] for r in rows)
print(f"total collective bytes/dev: {tot:.3e} over {len(rows)} sites")
for r in rows[:18]:
    print(f"{r[0]:.3e} (={r[1]:.2e} x{r[2]:4d}) {r[3]:20s} {r[4]}")
