"""Region-attributed cost breakdown of one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json
from repro.launch.dryrun import lower_cell
from repro.roofline.hlo_cost import module_cost, module_region_cost

PATTERNS = {
    "attn_interior": r"kv_step|one_q_chunk|chunked_attention",
    "attn_proj": r"attn.*(einsum|dot_general)|decode_attention",
    "moe": r"moe|top_k|cumsum|segment",
    "wkv": r"chunked_wkv|wkv",
    "rglru": r"rglru|associative_scan|causal_conv",
    "optimizer": r"train_step/(add|mul|sub|sqrt|pow|min|max|div|integer_pow)$|prox",
    "loss_head": r"log_softmax|logsumexp|take_along|softmax_xent|nll",
    "embed": r"take\b|gather.*embed",
    "transpose_copy": r"transpose|copy",
}

arch, shape, mesh = sys.argv[1], sys.argv[2], sys.argv[3] if len(sys.argv)>3 else "single"
compiled, cfg, shp, meta = lower_cell(arch, shape, mesh == "multi")
txt = compiled.as_text()
total = module_cost(txt)
regions = module_region_cost(txt, PATTERNS)
print(f"== {arch} {shape} {mesh}  (per-device)")
print(f"total: flops={total.flops:.3e} bytes={total.bytes:.3e} coll={total.total_collective_bytes:.3e}")
print(f"{'region':16s} {'flops':>11s} {'bytes':>11s} {'coll_bytes':>11s}")
for tag, c in sorted(regions.items(), key=lambda kv: -kv[1].bytes):
    print(f"{tag:16s} {c.flops:11.3e} {c.bytes:11.3e} {sum(c.collective_bytes.values()):11.3e}  {dict((k, f'{v:.2e}') for k,v in c.collective_bytes.items())}")
mem = compiled.memory_analysis()
print(f"mem/dev GB: arg={mem.argument_size_in_bytes/2**30:.2f} temp={mem.temp_size_in_bytes/2**30:.2f}")
