"""Render the optimized roofline table (+ flash-adjusted columns) from
experiments/dryrun/ artifacts, in EXPERIMENTS.md format."""
import glob
import json
import sys

root = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
rows = []
for f in sorted(glob.glob(f"{root}/*__single.json")):
    r = json.load(open(f))
    if not r.get("ok"):
        rows.append((r["arch"], r["shape"], None, r.get("error", "")[:60]))
        continue
    ro = r["roofline"]
    fl = r.get("roofline_flash")
    rows.append((ro["arch"], ro["shape"], ro, fl,
                 r["memory"]["peak_per_device_gb"]))

print(f"{'arch':24s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
      f"{'coll_s':>8s} {'dom':>6s} {'useful':>7s} {'roof%':>7s} "
      f"{'GB/dev':>7s} | {'flash roof%':>11s} {'flash dom':>9s}")
for row in rows:
    if row[2] is None:
        print(f"{row[0]:24s} {row[1]:12s} FAILED {row[3]}")
        continue
    arch, shape, ro, fl, gb = row
    flash = (f"{100*fl['roofline_fraction']:10.2f}% {fl['dominant']:>9s}"
             if fl else f"{'—':>11s} {'—':>9s}")
    print(f"{arch:24s} {shape:12s} {ro['compute_s']:8.3f} "
          f"{ro['memory_s']:8.3f} {ro['collective_s']:8.3f} "
          f"{ro['dominant'][:6]:>6s} {100*ro['useful_flops_ratio']:6.1f}% "
          f"{100*ro['roofline_fraction']:6.2f}% {gb:7.2f} | {flash}")

multi_ok = sum(1 for f in glob.glob(f"{root}/*__multi.json")
               if json.load(open(f)).get("ok"))
single_ok = sum(1 for r in rows if r[2] is not None)
print(f"\nsingle-pod ok: {single_ok}  multi-pod ok: {multi_ok}")
