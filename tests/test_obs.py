"""Telemetry subsystem: metrics registry, request tracer, profiling hooks.

The load-bearing guarantees:
  * percentile helpers: ``pct([])`` is ``None`` — an empty completions list
    must never crash ``np.percentile`` or fabricate a 0.0 SLO,
  * registry: get-or-create identity, labeled series, kind/label conflicts
    rejected, snapshot + Prometheus exposition round-trip through the
    strict reader (``parse_prometheus``),
  * tracer: span nesting/ordering invariants under a fake clock, a
    preempt-requeue produces a *resumed* span chain (never overlapping
    duplicates), Chrome schema validation (required keys, monotonic ts),
  * engine end-to-end: Prometheus counters match scheduler / allocator /
    prefix-cache ground truth, and per-request trace span durations
    reconcile with the stats dict's ttft/latency percentiles (± a tick),
  * profiler: eager kernel calls are wall-timed, traced (in-jit) calls are
    only counted; the disabled path (no active profiler, NULL registry,
    NULL tracer) stays no-op cheap.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model_zoo import build
from repro.obs.metrics import (MetricsRegistry, NULL_REGISTRY, parse_prometheus,
                               pct, prom_value, slo_summary)
from repro.obs.profile import (Profiler, TrainTelemetry, kernel_call,
                               sparsity_telemetry_fn)
from repro.obs.trace import (ENGINE_TID, NULL_TRACER, Tracer,
                             validate_chrome_trace)
from repro.serve import api
from repro.serve.engine import EngineConfig, ServeEngine

GEN = 5


@pytest.fixture(scope="module")
def model():
    return build("smollm-360m", reduced=True)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.PRNGKey(0))


def _prompts(lens, vocab, seed=7):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (L,), 0, vocab), np.int32)
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# percentile / SLO helpers
# ---------------------------------------------------------------------------

def test_pct_empty_is_none():
    assert pct([], 50) is None
    assert pct([], 95) is None
    assert pct(iter([]), 50) is None


def test_pct_values():
    assert pct([3.0], 50) == 3.0
    assert pct([1.0, 2.0, 3.0], 50) == 2.0


def test_slo_summary_empty():
    s = slo_summary([], [], 0, n_preempted=0)
    assert s["n_requests"] == 0 and s["n_preempted"] == 0
    assert s["ttft_p50_s"] is None and s["latency_p95_s"] is None


def test_slo_summary_extra_keys():
    s = slo_summary([0.1], [0.5], 1, n_redispatched=2)
    assert s["n_redispatched"] == 2
    assert s["ttft_p50_s"] == pytest.approx(0.1)
    assert s["latency_p50_s"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_identity():
    m = MetricsRegistry()
    c1 = m.counter("repro_x_total", "x")
    c2 = m.counter("repro_x_total")
    assert c1 is c2
    c1.inc()
    c2.inc(2)
    assert c1.value() == 3


def test_registry_conflicts_rejected():
    m = MetricsRegistry()
    m.counter("repro_x_total")
    with pytest.raises(ValueError):
        m.gauge("repro_x_total")
    m.counter("repro_y_total", labelnames=("kind",))
    with pytest.raises(ValueError):
        m.counter("repro_y_total", labelnames=("other",))
    with pytest.raises(ValueError):
        m.counter("bad name!")


def test_counter_labels_and_total():
    m = MetricsRegistry()
    c = m.counter("repro_tok_total", labelnames=("kind",))
    c.inc(3, kind="prefill")
    c.inc(2, kind="decode")
    assert c.value(kind="prefill") == 3
    assert c.total() == 5
    with pytest.raises(ValueError):
        c.inc()                               # labeled counter needs labels
    with pytest.raises(ValueError):
        m.counter("repro_plain_total").inc(1, kind="x")   # and vice versa
    with pytest.raises(ValueError):
        c.inc(-1, kind="decode")              # counters only go up


def test_histogram_bounded_window():
    m = MetricsRegistry()
    h = m.histogram("repro_h", max_samples=8)
    for i in range(100):
        h.observe(float(i))
    assert h.count() == 100                    # exact count survives the ring
    assert h.sum() == sum(range(100))
    assert len(h._series[()].samples) == 8     # bounded reservoir
    assert h.percentile(50) >= 90              # window holds recent values


def test_snapshot_shape():
    m = MetricsRegistry()
    m.counter("repro_a_total").inc(2)
    m.gauge("repro_g").set(7)
    m.histogram("repro_h").observe(1.5)
    snap = m.snapshot()
    assert snap["repro_a_total"]["type"] == "counter"
    assert snap["repro_a_total"]["series"][0]["value"] == 2
    assert snap["repro_g"]["series"][0]["value"] == 7
    hs = snap["repro_h"]["series"][0]
    assert hs["count"] == 1 and hs["p50"] == 1.5
    json.dumps(snap)                           # JSON-safe by construction


def test_prometheus_round_trip():
    m = MetricsRegistry()
    m.counter("repro_a_total", "a counter").inc(3)
    c = m.counter("repro_b_total", labelnames=("kind",))
    c.inc(2, kind="prefill")
    c.inc(5, kind="decode")
    m.gauge("repro_g", "a gauge").set(1.25)
    h = m.histogram("repro_h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    text = m.to_prometheus()
    parsed = parse_prometheus(text)
    assert prom_value(parsed, "repro_a_total") == 3
    assert prom_value(parsed, "repro_b_total", kind="decode") == 5
    assert prom_value(parsed, "repro_b_total") == 7      # label-free sums
    assert prom_value(parsed, "repro_g") == 1.25
    assert prom_value(parsed, "repro_h_count") == 3
    assert prom_value(parsed, "repro_h_sum") == 6.0
    assert prom_value(parsed, "repro_h", quantile="0.5") == 2.0
    assert prom_value(parsed, "repro_missing") is None


def test_prometheus_extra_labels():
    m = MetricsRegistry()
    m.counter("repro_a_total").inc(4)
    parsed = parse_prometheus(m.to_prometheus({"replica": 1}))
    assert prom_value(parsed, "repro_a_total", replica="1") == 4


def test_parse_prometheus_rejects_garbage():
    with pytest.raises(ValueError):
        parse_prometheus("this is not { a sample\n")


def test_null_registry_is_inert():
    c = NULL_REGISTRY.counter("repro_x_total", labelnames=("kind",))
    c.inc(5, kind="prefill")
    assert c.value(kind="prefill") == 0 and c.total() == 0
    h = NULL_REGISTRY.histogram("repro_h")
    h.observe(1.0)
    assert h.count() == 0 and h.percentile(50) is None
    assert NULL_REGISTRY.snapshot() == {}
    assert NULL_REGISTRY.to_prometheus() == ""


# ---------------------------------------------------------------------------
# tracer (fake clock)
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]

    def advance(ds):
        t[0] += ds

    return clock, advance


def _spans(tr, tid=None, name=None):
    return [e for e in tr.events if e["ph"] == "X"
            and (tid is None or e["tid"] == tid)
            and (name is None or e["name"] == name)]


def test_tracer_lifecycle_spans():
    clock, advance = _fake_clock()
    tr = Tracer(clock=clock)
    tr.request_submit(0, priority=1, n_prompt=8)
    advance(0.010)
    tr.request_admit(0, resumed=False, n_cached=0)
    advance(0.020)
    tr.request_first_token(0)
    tr.request_decode(0)
    advance(0.030)
    tr.request_finish(0)

    tid = tr._tid(0)
    spans = _spans(tr, tid=tid)
    assert [s["name"] for s in sorted(spans, key=lambda s: s["ts"])] == \
        ["wait", "prefill", "decode"]
    # exactly one phase open at a time: spans tile the timeline
    spans.sort(key=lambda s: s["ts"])
    for a, b in zip(spans, spans[1:]):
        assert a["ts"] + a["dur"] == b["ts"]
    assert spans[0]["dur"] == 10_000 and spans[1]["dur"] == 20_000
    names = [e["name"] for e in tr.events if e["ph"] == "i"]
    assert names == ["submit", "first_token", "done"]


def test_tracer_preempt_resumed_chain_not_duplicate():
    clock, advance = _fake_clock()
    tr = Tracer(clock=clock)
    tr.request_submit(7, priority=2, n_prompt=4)
    advance(0.001)
    tr.request_admit(7, resumed=False, n_cached=0)
    advance(0.001)
    tr.request_decode(7)
    tr.request_decode(7)                       # per-token: idempotent
    advance(0.001)
    tr.request_preempt(7)
    advance(0.005)
    tr.request_admit(7, resumed=True, n_cached=0)
    advance(0.001)
    tr.request_decode(7)
    advance(0.001)
    tr.request_finish(7)

    tid = tr._tid(7)
    spans = sorted(_spans(tr, tid=tid), key=lambda s: s["ts"])
    assert [s["name"] for s in spans] == \
        ["wait", "prefill", "decode", "wait", "prefill", "decode"]
    # resumed chain, never overlapping duplicates
    for a, b in zip(spans, spans[1:]):
        assert a["ts"] + a["dur"] <= b["ts"]
    assert spans[3]["args"]["resumed"] is True
    assert spans[4]["args"]["resumed"] is True
    # idempotent phase(): only ONE decode span per admission
    assert sum(s["name"] == "decode" for s in spans) == 2


def test_tracer_engine_span_nesting():
    clock, advance = _fake_clock()
    tr = Tracer(clock=clock)
    t0 = tr.now_us()
    advance(0.002)
    tr.complete_span("schedule", t0)
    with tr.span("step", width=4):
        advance(0.003)
    tr.complete_span("tick", t0, width=4)
    doc = tr.to_chrome()
    validate_chrome_trace(doc)
    body = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    # parent (longest) first at equal ts; children contained in the tick
    assert body[0]["name"] == "tick"
    tick = body[0]
    for child in body[1:]:
        assert tick["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= tick["ts"] + tick["dur"]


def test_chrome_schema_and_metadata():
    clock, advance = _fake_clock()
    tr = Tracer(clock=clock)
    tr.request_submit(3, priority=0, n_prompt=2)
    advance(0.001)
    tr.request_finish(3)
    doc = tr.to_chrome(process_name="test-proc")
    events = validate_chrome_trace(doc)
    meta = [e for e in events if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} >= \
        {"test-proc", "engine", "request 3"}
    for e in events:
        for k in ("name", "ph", "ts", "pid", "tid"):
            assert k in e


def test_validate_chrome_trace_rejects():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "traceEvents"})
    bad = {"traceEvents": [{"name": "a", "ph": "i", "ts": 5, "pid": 0,
                            "tid": 0},
                           {"name": "b", "ph": "i", "ts": 1, "pid": 0,
                            "tid": 0}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)             # ts not monotonic
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X",
                                                "ts": 0, "pid": 0,
                                                "tid": 0}]})  # X without dur


def test_null_tracer_inert():
    NULL_TRACER.request_submit(0, 0, 0)
    NULL_TRACER.request_finish(0)
    with NULL_TRACER.span("x"):
        pass
    assert NULL_TRACER.events == []
    assert NULL_TRACER.to_chrome()["traceEvents"] == []


# ---------------------------------------------------------------------------
# profiler + train telemetry
# ---------------------------------------------------------------------------

def test_kernel_call_passthrough_when_inactive():
    assert kernel_call("t/id", lambda x: x + 1, 41) == 42


def test_profiler_times_eager_counts_traced():
    def f(x):
        return kernel_call("t/f", jnp.sin, x)

    with Profiler() as p:
        kernel_call("t/f", jnp.sin, jnp.ones((4,)))       # eager: timed
        jax.jit(f)(jnp.ones((4,)))                        # traced: counted
    r = p.summary()["t/f"]
    assert r["n_calls"] == 1 and r["total_ms"] > 0 and r["mean_ms"] > 0
    assert r["n_traced"] == 1
    assert "t/f" in p.format_summary()
    # deactivated on exit
    assert kernel_call("t/f", lambda: 7) == 7
    assert p.summary()["t/f"]["n_calls"] == 1


def test_train_telemetry_jsonl(tmp_path):
    path = str(tmp_path / "run.jsonl")
    tel = TrainTelemetry(path)
    tel.emit({"phase": "spc", "step": 0, "loss": np.float32(1.5)})
    tel.emit({"phase": "debias", "step": 1, "loss": 1.0})
    tel.close()
    recs = [json.loads(l) for l in open(path)]
    assert tel.n_records == 2 and len(recs) == 2
    assert recs[0]["phase"] == "spc" and recs[0]["loss"] == 1.5
    assert recs[1]["phase"] == "debias"


def test_sparsity_telemetry_fn(model, params):
    fn = sparsity_telemetry_fn((8, 64), lam=0.5)
    rec = fn(params)
    assert 0.0 <= rec["block_sparsity"] <= 1.0
    assert rec["group_l1_penalty"] > 0
    assert rec["layer_block_sparsity"]          # at least one target layer
    for v in rec["layer_block_sparsity"].values():
        assert 0.0 <= v <= 1.0


# ---------------------------------------------------------------------------
# engine end-to-end: counters vs ground truth, trace vs stats
# ---------------------------------------------------------------------------

def test_engine_counters_match_ground_truth(model, params):
    tracer = Tracer()
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                                   max_seq_len=16 + GEN),
                      tracer=tracer)
    prompts = _prompts([5, 12, 3, 16, 9], model.cfg.vocab)
    out = eng.run([(p, GEN) for p in prompts])
    stats = out["stats"]

    parsed = parse_prometheus(eng.metrics.to_prometheus())
    assert prom_value(parsed, "repro_engine_ticks_total") == eng.n_ticks
    assert prom_value(parsed, "repro_sched_prefill_chunks_total") == \
        eng.scheduler.n_prefill_chunks
    assert prom_value(parsed, "repro_sched_tokens_total") == \
        eng.scheduler.n_scheduled_tokens
    assert prom_value(parsed, "repro_engine_requests_total") == len(prompts)
    assert prom_value(parsed, "repro_engine_requests_finished_total") == \
        len(prompts)
    assert prom_value(parsed, "repro_engine_generated_tokens_total") == \
        stats["n_generated"]
    # every admission was fresh (no preemption in this mix)
    assert prom_value(parsed, "repro_sched_admissions_total",
                      resumed="false") == len(prompts)
    # an untouched counter has no series -> absent from the exposition
    assert prom_value(parsed, "repro_sched_preemptions_total") is None
    assert eng.scheduler.n_preemptions == 0
    # allocator churn balances once every request released its pages
    allocs = prom_value(parsed, "repro_page_allocs_total")
    frees = prom_value(parsed, "repro_page_frees_total")
    assert allocs > 0 and allocs == frees
    assert prom_value(parsed, "repro_pages_in_use") == 0
    assert prom_value(parsed, "repro_pages_free") == eng.allocator.n_free
    # tick-width counts sum over the labeled series
    widths = {lab_d["width"]
              for (n, lab), _ in parsed.items() if n == "repro_engine_ticks_total"
              for lab_d in [dict(lab)]}
    assert widths <= {"1", "8"}                # decode + prefill_chunk widths

    # trace reconciles with stats: per-request done-submit vs latency p50
    doc = tracer.to_chrome()
    validate_chrome_trace(doc)
    by_rid_inst = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "i" and e.get("cat") == "request":
            by_rid_inst.setdefault(e["args"]["rid"], {})[e["name"]] = e["ts"]
    tick_durs = [e["dur"] for e in doc["traceEvents"]
                 if e["ph"] == "X" and e["name"] == "tick"]
    tol_s = (max(tick_durs) / 1e6) * 1.5 + 0.05   # ± a tick (+ sched slack)
    lats = [(inst["done"] - inst["submit"]) / 1e6
            for inst in by_rid_inst.values()]
    ttfts = [(inst["first_token"] - inst["submit"]) / 1e6
             for inst in by_rid_inst.values()]
    assert len(lats) == len(prompts)
    assert abs(float(np.percentile(lats, 50)) - stats["latency_p50_s"]) \
        <= tol_s
    assert abs(float(np.percentile(ttfts, 50)) - stats["ttft_p50_s"]) \
        <= tol_s


def test_engine_preemption_resumed_spans_and_counters(model, params):
    tracer = Tracer()
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, prefill_chunk=8, page_size=4,
                                   max_seq_len=32),
                      tracer=tracer)
    prompts = _prompts([6, 6, 6, 6], model.cfg.vocab)
    finished = []
    for i in range(3):                         # batch requests occupy slots
        eng.submit(api.Request(prompt=prompts[i], max_new_tokens=16,
                               priority="batch"))
    for _ in range(4):
        finished.extend(eng.step())
    eng.submit(api.Request(prompt=prompts[3], max_new_tokens=4,
                           priority="interactive"))
    while eng.scheduler.has_work():
        finished.extend(eng.step())
    assert eng.scheduler.n_preemptions >= 1

    parsed = parse_prometheus(eng.metrics.to_prometheus())
    assert prom_value(parsed, "repro_sched_preemptions_total") == \
        eng.scheduler.n_preemptions
    resumed = prom_value(parsed, "repro_sched_admissions_total",
                         resumed="true")
    assert resumed is not None and resumed >= 1
    assert prom_value(parsed, "repro_engine_requests_total",
                      request_class="0") == 1      # the interactive arrival

    # preempted request: resumed span chain, never overlapping duplicates
    doc = tracer.to_chrome()
    validate_chrome_trace(doc)
    preempted_tids = {e["tid"] for e in doc["traceEvents"]
                      if e["ph"] == "i" and e["name"] == "preempt"}
    assert preempted_tids
    for tid in preempted_tids:
        spans = sorted([e for e in doc["traceEvents"]
                        if e["ph"] == "X" and e["tid"] == tid],
                       key=lambda e: e["ts"])
        assert sum(s["name"] == "wait" for s in spans) >= 2
        assert any(s["args"].get("resumed") for s in spans)
        for a, b in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] <= b["ts"]


def test_engine_prefix_cache_counters(model, params):
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                                   max_seq_len=32, prefix_cache=True))
    shared = _prompts([16], model.cfg.vocab)[0]
    tails = _prompts([4, 4], model.cfg.vocab, seed=11)
    wave = [(np.concatenate([shared, t]), GEN) for t in tails]
    eng.run(wave)                              # cold: populates the cache
    eng.run(wave)                              # warm: hits
    c = eng.prefix_cache
    parsed = parse_prometheus(eng.metrics.to_prometheus())
    assert prom_value(parsed, "repro_prefix_queries_total") == c.n_queries
    assert prom_value(parsed, "repro_prefix_hit_queries_total") == \
        c.n_hit_queries
    assert prom_value(parsed, "repro_prefix_tokens_hit_total") == \
        c.tokens_hit
    assert c.tokens_hit > 0                    # the warm wave actually hit
    assert prom_value(parsed, "repro_prefix_cached_pages") == c.n_cached_pages
    inserted = prom_value(parsed, "repro_prefix_inserted_pages_total")
    evicted = prom_value(parsed, "repro_prefix_evictions_total") or 0
    assert inserted - evicted == c.n_cached_pages


def test_engine_stats_read_from_registry(model, params):
    """`engine._stats` counters are registry-backed — zeroing the registry
    path (NULL) still yields a structurally complete stats dict."""
    from repro.obs.metrics import NullRegistry

    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, prefill_chunk=8, page_size=4,
                                   max_seq_len=16),
                      metrics=NullRegistry())
    out = eng.run([(p, GEN) for p in _prompts([4, 6], model.cfg.vocab)])
    s = out["stats"]
    assert s["n_generated"] == 2 * GEN         # records, not registry
    assert eng.n_ticks == 0                    # registry-backed -> inert
    assert eng.scheduler.n_prefill_chunks == 0
    assert eng.metrics.to_prometheus() == ""


def test_disabled_telemetry_overhead():
    """The no-op path must stay a constant-time method call per site."""
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        NULL_TRACER.request_decode(1)
        NULL_REGISTRY.counter("x").inc()
    dt = time.perf_counter() - t0
    assert dt < 2.0                            # generous: ~µs per call pair
