"""Quantized BCSR serving (PaletteBCSR — Deep Compression stage 2).

Covers the acceptance criteria of the quantized-serving tentpole:
  * uint4 nibble packing round-trips bit-exactly,
  * ``quantize_bcsr`` preserves the sparsity pattern (code 0 == exact zero)
    and shares the BlockCSR index/gather tables by reference,
  * the palette kernel (fused dequant) matches the ref backend and the
    dequantize-then-BCSR oracle exactly, at 8 and 4 bits,
  * PaletteBCSR serving logits match the BCSR path: bit-exactly against the
    dequantized model, and within tolerance against the fp model at 8-bit,
  * real bytes: palette-quantized sparse store <= 1/3 of the fp32 BlockCSR
    store at realistic layer sizes (8-bit), <= 1/6 at 4-bit,
  * Checkpointer round-trips PaletteBCSR without densifying (codes stay
    packed on disk) and ``restore_compressed`` rebuilds the quantized plan,
  * the train --sparse --quantize-bits -> serve --ckpt-dir CLI loop serves
    from PaletteBCSR,
  * quantized weights are rejected by the retraining paths (serving-only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.models.model_zoo import build
from repro.sparse import ops as sparse_ops
from repro.sparse.compress import (CompressionPlan, bcsr_equiv_size_bytes,
                                   compress_params, compressed_size_bytes,
                                   dequantize_compressed, iter_bcsr,
                                   prune_blocks_for_plan, quantize_bcsr,
                                   quantize_compressed, split_trainable)
from repro.sparse.formats import (BlockCSR, PaletteBCSR, dense_to_bcsr,
                                  pack_uint4, unpack_uint4)

PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


def _block_sparse(shape=(512, 1024), block=(8, 64), keep=0.25, seed=0):
    """Random dense matrix with whole (br, bc) blocks zeroed."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=shape).astype(np.float32)
    br, bc = block
    occ = rng.random((shape[0] // br, shape[1] // bc)) < keep
    mask = np.kron(occ, np.ones((br, bc), bool))
    return w * mask


# ---------------------------------------------------------------------------
# Packing + format construction
# ---------------------------------------------------------------------------

def test_uint4_pack_roundtrip():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, size=(5, 8, 64)).astype(np.uint8)
    packed = pack_uint4(jnp.asarray(codes))
    assert packed.shape == (5, 8, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_uint4(packed)), codes)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_bcsr_preserves_pattern_and_indices(bits):
    m = dense_to_bcsr(_block_sparse(), block=(8, 64))
    q = quantize_bcsr(m, bits)
    assert isinstance(q, PaletteBCSR) and q.bits == bits
    # index/gather tables shared by reference — no extra copies
    assert q.col_idx is m.col_idx and q.gather_idx is m.gather_idx
    assert float(q.palette[0]) == 0.0
    # code 0 <-> exact zero: the sparsity pattern survives quantization
    deq = np.asarray(q.dequantize().data)
    orig = np.asarray(m.data)
    np.testing.assert_array_equal(deq == 0, orig == 0)


def test_quantize_bcsr_exact_on_small_palette():
    """Weights drawn from a small, well-separated value set are represented
    exactly at 8-bit (k-means converges onto the values)."""
    rng = np.random.default_rng(1)
    levels = np.linspace(-1.0, 1.0, 9).astype(np.float32)
    w = levels[rng.integers(0, 9, size=(256, 512))]
    w[np.kron(rng.random((32, 8)) < 0.7,
              np.ones((8, 64), bool))] = 0.0
    m = dense_to_bcsr(w, block=(8, 64))
    q = quantize_bcsr(m, 8)
    np.testing.assert_allclose(np.asarray(q.dequantize().data),
                               np.asarray(m.data), atol=1e-6)


def test_quantize_bcsr_stacked_per_slice_palettes():
    ws = [_block_sparse(seed=s) for s in range(3)]
    ms = [dense_to_bcsr(w, block=(8, 64)) for w in ws]
    from repro.sparse.formats import pad_bcsr
    n_slots = max(m.data.shape[0] for m in ms)
    jmax = max(m.gather_idx.shape[1] for m in ms)
    jmax_t = max(m.gather_t_idx.shape[1] for m in ms)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[pad_bcsr(m, n_slots, jmax, jmax_t) for m in ms])
    q = quantize_bcsr(stacked, 8)
    assert q.codes.ndim == 4 and q.palette.shape == (3, 256)
    deq = np.asarray(q.dequantize().data)
    for i, m in enumerate(ms):
        d = np.asarray(pad_bcsr(m, n_slots, jmax, jmax_t).data)
        np.testing.assert_array_equal((deq[i] == 0), (d == 0))


def test_quantize_bcsr_all_zero_slice():
    """A fully pruned (empty) BCSR quantizes to all-zero codes/palette."""
    m = dense_to_bcsr(np.zeros((64, 128), np.float32), block=(8, 64))
    q = quantize_bcsr(m, 8)
    assert np.all(np.asarray(q.codes) == 0)
    assert np.all(np.asarray(q.palette) == 0)
    np.testing.assert_array_equal(np.asarray(q.to_dense()),
                                  np.zeros((64, 128)))


# ---------------------------------------------------------------------------
# Kernel paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_palette_spmm_backend_symmetry(bits):
    w = _block_sparse(shape=(128, 256))
    q = quantize_bcsr(dense_to_bcsr(w, block=(8, 64)), bits)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(16, 256)),
                    jnp.float32)
    y_ref = sparse_ops.sparse_matmul(x, q, backend="ref")
    y_pal = sparse_ops.sparse_matmul(x, q, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               atol=1e-5, rtol=1e-5)
    # and both equal the dequantize-then-fp-BCSR oracle
    y_deq = sparse_ops.sparse_matmul(x, q.dequantize(), backend="ref")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_deq),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_palette_x_gradient_defined_on_both_backends(backend):
    """dx must exist (and agree with the dequantized-BCSR product) on both
    backends — serving code that differentiates through logits (saliency,
    grad-through-generate) must not diverge between CPU tests and TPU."""
    w = _block_sparse(shape=(128, 256))
    q = quantize_bcsr(dense_to_bcsr(w, block=(8, 64)), 8)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(8, 256)),
                    jnp.float32)

    def loss(xx):
        return jnp.sum(sparse_ops.sparse_matmul(xx, q, backend=backend) ** 2)

    g = jax.jit(jax.grad(loss))(x)
    y = sparse_ops.sparse_matmul(x, q.dequantize(), backend="ref")
    g_ref = np.asarray(
        sparse_ops.sparse_matmul_t(2.0 * y, q, backend="ref"))
    np.testing.assert_allclose(np.asarray(g), g_ref, atol=1e-3, rtol=1e-4)


def test_sparse_matmul_t_accepts_palette():
    w = _block_sparse(shape=(128, 256))
    m = dense_to_bcsr(w, block=(8, 64))
    q = quantize_bcsr(m, 8)
    dy = jnp.asarray(np.random.default_rng(4).normal(size=(8, 128)),
                     jnp.float32)
    out_q = sparse_ops.sparse_matmul_t(dy, q, backend="ref")
    out_d = sparse_ops.sparse_matmul_t(dy, q.dequantize(), backend="ref")
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_d),
                               atol=1e-6)


def test_palette_bytes_ratio():
    """The tentpole size criterion at realistic layer sizes: 8-bit palette
    store <= 1/3 of the fp32 BlockCSR store, 4-bit <= 1/6."""
    m = dense_to_bcsr(_block_sparse(shape=(1024, 1024)), block=(8, 64))
    q8, q4 = quantize_bcsr(m, 8), quantize_bcsr(m, 4)
    assert q8.bcsr_equiv_nbytes == m.nbytes
    assert 3 * q8.nbytes <= m.nbytes
    assert 6 * q4.nbytes <= m.nbytes


# ---------------------------------------------------------------------------
# Whole-model serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def quantized_setup():
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)
    qcp = quantize_compressed(cp, bits=8)
    return model, cp, qcp


def test_quantize_compressed_plan_and_leaves(quantized_setup):
    _, cp, qcp = quantized_setup
    assert qcp.plan.quantize_bits == 8
    kinds = {type(m).__name__ for _, m in iter_bcsr(qcp)}
    assert kinds == {"PaletteBCSR"}
    # bytes: quantized total strictly below fp BCSR total, and the fp
    # equivalent accounting reproduces the unquantized total
    assert compressed_size_bytes(qcp) < compressed_size_bytes(cp)
    assert bcsr_equiv_size_bytes(qcp) == compressed_size_bytes(cp)


def test_palette_serve_matches_dequantized_bitexact(quantized_setup):
    """Serving from PaletteBCSR == serving the dequantized BCSR model: the
    fused-dequant kernel path introduces no error of its own."""
    model, _, qcp = quantized_setup
    dcp = dequantize_compressed(qcp)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                model.cfg.vocab)
    cache_q = model.init_cache(2, 16)
    cache_d = model.init_cache(2, 16)
    lq, cache_q = jax.jit(model.prefill)(qcp, prompt, cache_q)
    ld, cache_d = jax.jit(model.prefill)(dcp, prompt, cache_d)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(ld),
                               atol=1e-5, rtol=1e-5)
    tok = jnp.argmax(lq, -1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    lq2, _ = step(qcp, tok, cache_q, jnp.int32(8))
    ld2, _ = step(dcp, tok, cache_d, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(lq2), np.asarray(ld2),
                               atol=1e-5, rtol=1e-5)


def test_palette_serve_near_fp_bcsr_at_8bit(quantized_setup):
    """8-bit logits-parity tolerance vs the unquantized BCSR path (255
    clusters per layer keep distortion small end-to-end)."""
    model, cp, qcp = quantized_setup
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                model.cfg.vocab)
    lb, _ = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 16))
    lq, _ = jax.jit(model.prefill)(qcp, prompt, model.init_cache(2, 16))
    err = float(jnp.abs(lq - lb).max())
    scale = float(jnp.abs(lb).max())
    assert err <= 0.05 * max(scale, 1.0), (err, scale)


def test_palette_checkpoint_roundtrip(tmp_path, quantized_setup):
    _, _, qcp = quantized_setup
    ckpt = Checkpointer(str(tmp_path), keep_n=2)
    ckpt.save(3, qcp)
    fmts = {e["format"] for e in ckpt.manifest(3)["leaves"]}
    assert "palette_bcsr" in fmts and "bcsr" not in fmts
    back = ckpt.restore(3, like=qcp)
    flat_a, tda = jax.tree_util.tree_flatten(qcp)
    flat_b, tdb = jax.tree_util.tree_flatten(back)
    assert tda == tdb                         # bits/metas included
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # template-free restore rebuilds PaletteBCSR leaves (no densification)
    back2 = ckpt.restore_compressed(3)
    kinds = {type(m).__name__ for _, m in iter_bcsr(back2)}
    assert kinds == {"PaletteBCSR"}
    m0 = next(m for _, m in iter_bcsr(back2))
    assert m0.bits == 8 and m0.codes.dtype == jnp.uint8


def test_quantized_is_serving_only(quantized_setup):
    _, _, qcp = quantized_setup
    with pytest.raises(TypeError, match="serving-only"):
        split_trainable(qcp)
    from repro.kernels.bsr_sddmm import ops as sddmm_kops
    m = next(m for _, m in iter_bcsr(qcp))
    with pytest.raises(TypeError, match="not .*trainable|PaletteBCSR"):
        sddmm_kops.bsr_weight_grad(jnp.zeros((8, m.shape[1])),
                                   jnp.zeros((8, m.shape[0])), m)


# ---------------------------------------------------------------------------
# End-to-end CLI: train --sparse --quantize-bits 8 -> serve --ckpt-dir
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_train_quantized_to_serve(tmp_path, capsys):
    from repro.launch import serve as serve_launch
    from repro.launch import train as train_launch

    cp, _, _, report = train_launch.main(
        ["--arch", "smollm-360m", "--reduced", "--sparse",
         "--quantize-bits", "8", "--steps", "12", "--debias-steps", "3",
         "--batch", "2", "--seq", "16", "--lr", "3e-3",
         "--compress", "group_l1:100", "--block", "8", "64",
         "--ckpt-dir", str(tmp_path), "--log-every", "4"])
    kinds = {type(m).__name__ for _, m in iter_bcsr(cp)}
    assert kinds == {"PaletteBCSR"}, "checkpointed model is not quantized"
    assert report["palette_bytes"] < report["bcsr_bytes"]

    out = serve_launch.main(
        ["--arch", "smollm-360m", "--reduced", "--sparse",
         "--ckpt-dir", str(tmp_path), "--batch", "2",
         "--prompt-len", "4", "--gen", "4"])
    assert out.shape == (2, 4)
    printed = capsys.readouterr().out
    assert "pal8" in printed, "serve did not report the palette format"
    assert "palette=" in printed and "bcsr=" in printed
