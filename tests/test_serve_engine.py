"""Continuous-batching engine: paged KV + scheduler + mixed-step parity.

The load-bearing guarantees:
  * per-token parity (greedy, tolerance 0) between the engine and the
    sequential ``generate`` path for dense, BlockCSR, and PaletteBCSR
    weights — >= 8 concurrent mixed-length requests for the quantized form,
  * chunked prefill: a prompt longer than ``prefill_chunk`` prefills across
    multiple ticks (interleaved with decode) and still matches,
  * the paged mixed step's logits match ``Model.prefill`` on the same
    prompt (the attention-path equivalence, not just argmax),
  * scheduler mechanics: FCFS admission, token budget (decode never
    stalls), slot/page recycling, page-pressure queueing, EOS stop,
    per-request streaming callbacks,
  * hybrid prefill: with ``first_chunk`` set, a long prompt's FIRST tick
    runs at the jumbo width and exactly three tick widths
    ({1, prefill_chunk, first_chunk}) ever compile,
  * the pallas paged-attention backend (fused page-gather flash-decode
    kernel, interpret mode off-TPU) keeps per-token parity end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model_zoo import build
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.paged_kv import (PageAllocator, init_paged_cache,
                                  paged_cache_bytes, pages_for)
from repro.serve.scheduler import Request, Scheduler
from repro.serve.step import generate
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   prune_blocks_for_plan, quantize_compressed)

GEN = 5


@pytest.fixture(scope="module")
def model():
    return build("smollm-360m", reduced=True)


@pytest.fixture(scope="module")
def params_by_format(model):
    params = model.init(jax.random.PRNGKey(0))
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.5)
    pruned = prune_blocks_for_plan(params, plan, 0.85)
    cp = compress_params(pruned, plan)
    return {"dense": pruned, "bcsr": cp,
            "palette8": quantize_compressed(cp, bits=8)}


def _prompts(lens, vocab, seed=7):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (L,), 0, vocab), np.int32)
            for i, L in enumerate(lens)]


def _assert_engine_matches_generate(model, params, lens, *, max_batch,
                                    prefill_chunk=8, gen=GEN):
    prompts = _prompts(lens, model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=max_batch,
                                   prefill_chunk=prefill_chunk, page_size=4,
                                   max_seq_len=max(lens) + gen))
    out = eng.run([(p, gen) for p in prompts])
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], gen))[0]
        np.testing.assert_array_equal(
            out["results"][rid], ref,
            err_msg=f"request {rid} (prompt_len={len(p)})")
    return out


@pytest.mark.parametrize("fmt", ["dense", "bcsr"])
def test_engine_token_parity(model, params_by_format, fmt):
    """4 concurrent mixed-length requests, tokens == generate() exactly."""
    _assert_engine_matches_generate(model, params_by_format[fmt],
                                    [5, 12, 3, 12], max_batch=4)


def test_engine_eight_concurrent_palette(model, params_by_format):
    """>= 8 concurrent mixed-length requests from PaletteBCSR weights with
    per-token parity — incl. prompts longer than the prefill chunk."""
    out = _assert_engine_matches_generate(
        model, params_by_format["palette8"],
        [5, 12, 3, 20, 5, 12, 3, 20], max_batch=8)
    s = out["stats"]
    assert s["n_requests"] == 8
    assert s["n_generated"] == 8 * GEN
    # 20-token prompts at chunk 8 really were split: ceil(20/8)=3 chunks
    assert s["n_prefill_chunks"] >= 2 * 3 + 6


def test_chunked_prefill_interleaves_with_decode(model, params_by_format):
    """A long prompt admitted mid-flight prefills in chunks while the
    running request keeps decoding — and both still match generate()."""
    params = params_by_format["bcsr"]
    prompts = _prompts([3, 20], model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, prefill_chunk=8, page_size=4,
                                   max_seq_len=32))
    out = eng.run([(p, GEN) for p in prompts])
    assert eng.scheduler.n_prefill_chunks == 1 + 3   # ceil(3/8) + ceil(20/8)
    # the long prompt needed 3 prefill ticks; the short request decoded
    # during them (ticks < sequential sum)
    assert eng.n_ticks < (1 + GEN) + (3 + GEN)
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(out["results"][rid], ref)


def test_paged_step_logits_match_prefill(model, params_by_format):
    """One paged mixed step over a whole prompt == Model.prefill logits
    (the attention-path equivalence underlying token parity)."""
    params = params_by_format["bcsr"]
    L, ps = 12, 4
    prompt = _prompts([L], model.cfg.vocab)[0]
    n_pages = pages_for(L, ps)
    pools = init_paged_cache(model, n_pages + 1, ps)
    table = np.zeros((1, n_pages), np.int32)
    table[0] = np.arange(1, n_pages + 1)
    logits, _ = model.paged_step(
        params, jnp.asarray(prompt)[None, :], pools, jnp.asarray(table),
        jnp.zeros((1,), jnp.int32), jnp.full((1,), L, jnp.int32))
    cache = model.init_cache(1, L + 1)
    ref, _ = model.prefill(params, jnp.asarray(prompt)[None, :], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_engine_moe_arch_parity(params_by_format):
    """MoE FFNs are per-token, so the paged engine covers attention+MoE
    architectures too (olmoe reduced)."""
    moe_model = build("olmoe-1b-7b", reduced=True)
    params = moe_model.init(jax.random.PRNGKey(1))
    _assert_engine_matches_generate(moe_model, params, [4, 9], max_batch=2,
                                    gen=3)


def test_engine_rejects_unknown_layer_kind():
    """Graceful degrade for layer kinds outside attn/rglru/rwkv coverage:
    a clear message naming the kind + the sequential-path suggestion, not a
    raw traceback. (Recurrent archs themselves are covered — see
    tests/test_engine_recurrent.py.)"""
    import dataclasses

    from repro.models.model_zoo import get_config
    from repro.models.transformer import make_model

    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              block_pattern=("ssm",))
    fake = make_model(cfg)
    assert fake.paged_step is None
    with pytest.raises(NotImplementedError, match=r"'ssm'.*without --engine"):
        ServeEngine(fake, {}, EngineConfig())
    with pytest.raises(NotImplementedError, match=r"'ssm'"):
        init_paged_cache(fake, 8, 4)


def test_engine_streaming_callbacks_and_eos(model, params_by_format):
    params = params_by_format["bcsr"]
    prompt = _prompts([6], model.cfg.vocab)[0]
    ref = np.asarray(generate(model, params, prompt[None, :], GEN))[0]

    got = []
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, prefill_chunk=8, page_size=4,
                                   max_seq_len=16))
    rid = eng.submit(prompt, GEN,
                     stream=lambda r, tok, done: got.append((r, tok, done)))
    while eng.scheduler.has_work():
        eng.step()
    assert [t for _, t, _ in got] == ref.tolist()     # streamed in order
    assert [d for _, _, d in got] == [False] * (GEN - 1) + [True]
    assert all(r == rid for r, _, _ in got)

    # EOS recycles the slot early: stop at the first occurrence of eos_id
    eos = int(ref[2])
    stop = int(np.flatnonzero(ref == eos)[0])         # greedy may repeat
    eng2 = ServeEngine(model, params,
                       EngineConfig(max_batch=2, prefill_chunk=8,
                                    page_size=4, max_seq_len=16))
    rid2 = eng2.submit(prompt, GEN, eos_id=eos)
    finished = []
    while eng2.scheduler.has_work():
        finished.extend(eng2.step())
    assert finished[0]["rid"] == rid2
    np.testing.assert_array_equal(finished[0]["tokens"], ref[:stop + 1])
    assert eng2.allocator.n_free == eng2.config.total_pages - 1  # recycled


def test_engine_page_pressure_queues_fcfs(model, params_by_format):
    """With pages for only ~2 concurrent requests, 4 requests still all
    complete with unchanged tokens: optimistic admission takes all four
    slots, page shortfall preempts the youngest, preempted requests
    resume (prompt + generated re-prefilled) and match generate()."""
    params = params_by_format["bcsr"]
    lens = [5, 9, 5, 9]
    prompts = _prompts(lens, model.cfg.vocab)
    # 16-token max_seq at page_size 4 -> 4 pages per request; 9 total pages
    # (minus trash page 0) fit exactly 2 in flight
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                                   max_seq_len=16, n_pages=9))
    out = eng.run([(p, GEN) for p in prompts])
    assert out["stats"]["n_requests"] == 4
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(out["results"][rid], ref)
    assert eng.allocator.n_free == 8                  # all pages recycled


# ---------------------------------------------------------------------------
# Scheduler / allocator mechanics (no model)
# ---------------------------------------------------------------------------

def _sched(capacity=2, chunk=4, n_pages=64, max_pages=8, budget=None,
           first_chunk=None):
    return Scheduler(capacity=capacity, prefill_chunk=chunk,
                     allocator=PageAllocator(n_pages), page_size=4,
                     max_pages=max_pages, token_budget=budget,
                     first_chunk=first_chunk)


def _req(rid, plen, gen=4, **kw):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=gen, **kw)


def test_scheduler_fcfs_admission_and_budget():
    s = _sched(capacity=2, chunk=4, budget=6)
    for i, plen in enumerate([10, 10, 10]):
        s.add(_req(i, plen))
    plan = s.next_tick()
    # two slots admitted FCFS; budget 6 = 4-chunk for slot 0 + 2 for slot 1
    assert plan.width == 4
    assert plan.n_tokens.tolist() == [4, 2]
    np.testing.assert_array_equal(plan.tokens[0], [0, 1, 2, 3])
    np.testing.assert_array_equal(plan.tokens[1, :2], [0, 1])
    assert plan.samples == []                 # nobody finished a prompt yet
    s.complete_tick(plan, np.zeros(2, np.int64))
    # decode comes off the budget first once a prompt completes
    for _ in range(3):
        plan = s.next_tick()
        s.complete_tick(plan, np.full(2, 7))
    assert any(st is not None and st.prompt_done for st in s.slots)


def test_scheduler_decode_never_stalls_during_prefill():
    s = _sched(capacity=2, chunk=4, budget=5)
    s.add(_req(0, 4, gen=8))
    p = s.next_tick()                         # prompt consumed in one chunk
    s.complete_tick(p, np.array([11, 11]))
    s.add(_req(1, 24, gen=2))                 # long prompt arrives
    seen_decode_during_prefill = False
    for _ in range(10):
        p = s.next_tick()
        if p is None:
            break
        if p.n_tokens[0] == 1 and p.n_tokens[1] > 0:
            seen_decode_during_prefill = True
        s.complete_tick(p, np.array([11, 11]))
    assert seen_decode_during_prefill


def test_scheduler_slot_recycling_frees_pages():
    s = _sched(capacity=1, chunk=4, n_pages=16)
    free0 = s.allocator.n_free
    s.add(_req(0, 4, gen=1))
    s.add(_req(1, 4, gen=1))                  # queued: capacity 1
    plan = s.next_tick()
    assert s.slots[0].req.rid == 0 and len(s.waiting) == 1
    done = s.complete_tick(plan, np.array([3]))
    assert done and done[0]["rid"] == 0       # gen=1: finished immediately
    plan = s.next_tick()                      # rid 1 admitted into the slot
    assert s.slots[0].req.rid == 1
    done = s.complete_tick(plan, np.array([3]))
    assert done[0]["rid"] == 1
    assert s.allocator.n_free == free0        # every page returned


def test_allocator_reserve_and_errors():
    a = PageAllocator(8)                      # pages 1..7 usable
    assert a.n_free == 7
    got = a.alloc(7)
    assert sorted(got) == list(range(1, 8))
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free(got[:3])
    assert a.n_free == 3
    with pytest.raises(ValueError):
        PageAllocator(1)


def test_allocator_churn_free_list_consistent():
    """Admit/finish/requeue cycles across many ticks: the trash page is
    never handed out, no page is ever double-owned, and the free list plus
    in-flight pages always partition {1..n_pages-1}."""
    rng = np.random.default_rng(0)
    s = _sched(capacity=3, chunk=4, n_pages=24, max_pages=4)
    universe = set(range(1, 24))
    rid = 0
    for _ in range(8):                        # waves of requests
        for _ in range(int(rng.integers(1, 5))):
            s.add(_req(rid, int(rng.integers(1, 9)),
                       gen=int(rng.integers(1, 4))))
            rid += 1
        for _ in range(40):                   # drive ticks with churn checks
            plan = s.next_tick()
            if plan is None:
                break
            in_flight = [p for sl in s.slots if sl is not None
                         for p in sl.pages]
            assert 0 not in in_flight and 0 not in s.allocator._free
            assert len(in_flight) == len(set(in_flight))      # no dup owners
            assert set(in_flight).isdisjoint(s.allocator._free)
            assert set(in_flight) | set(s.allocator._free) == universe
            s.complete_tick(plan, rng.integers(0, 50, s.capacity))
    assert not s.has_work()
    assert s.allocator.n_free == 23           # fully drained -> all free


def test_scheduler_recurrent_admission_page_free():
    """paged=False (pure-recurrent models): admission needs only a free
    slot — a request far beyond the page-derived cap is admitted and the
    allocator is never touched."""
    s = Scheduler(capacity=2, prefill_chunk=4,
                  allocator=PageAllocator(4), page_size=4, max_pages=2,
                  paged=False)
    s.add(_req(0, 64, gen=8))                 # 18 pages worth: fine
    s.add(_req(1, 64, gen=8))
    plan = s.next_tick()
    assert plan is not None
    assert all(sl is not None for sl in s.slots)
    assert s.allocator.n_free == 3            # untouched
    np.testing.assert_array_equal(s.page_table(), 0)   # all trash-page


def test_scheduler_rejects_oversized_request():
    s = _sched(max_pages=2)                   # 8-token cap at page_size 4
    with pytest.raises(ValueError):
        s.add(_req(0, 16, gen=4))
    with pytest.raises(ValueError):
        s.add(Request(rid=1, prompt=np.zeros(0, np.int32),
                      max_new_tokens=4))


# ---------------------------------------------------------------------------
# Jumbo first chunk (third compiled tick width) + pallas paged attention
# ---------------------------------------------------------------------------

def test_scheduler_jumbo_first_chunk_4k_prompt():
    """A 4k prompt's FIRST tick consumes the jumbo width; every later
    prefill tick is the regular chunk; exactly three widths ever appear."""
    s = _sched(capacity=1, chunk=32, n_pages=2048, max_pages=1100,
               first_chunk=512)
    s.add(_req(0, 4096, gen=2))
    plan = s.next_tick()
    assert plan.width == 512
    assert plan.n_tokens.tolist() == [512]
    s.complete_tick(plan, np.zeros(1, np.int64))
    widths = {512}
    while s.has_work():
        plan = s.next_tick()
        widths.add(plan.width)
        if plan.width > 1:                    # regular chunks after jumbo
            assert plan.width == 32
            assert plan.n_tokens.max() <= 32
        s.complete_tick(plan, np.full(1, 7))
    assert widths == {512, 32, 1}


def test_scheduler_jumbo_skips_short_prompts_and_validates():
    # a prompt that fits one regular chunk never triggers the jumbo width
    s = _sched(capacity=1, chunk=8, first_chunk=32)
    s.add(_req(0, 8, gen=2))
    assert s.next_tick().width == 8
    # jumbo width must exceed the regular chunk
    with pytest.raises(ValueError):
        _sched(chunk=8, first_chunk=8)


def test_engine_jumbo_first_chunk_three_widths(model, params_by_format):
    """Engine-level hybrid prefill: the long prompt's first tick runs at
    the jumbo width, exactly three step shapes compile, tokens still match
    generate()."""
    params = params_by_format["dense"]
    prompts = _prompts([20, 3], model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, prefill_chunk=8, page_size=4,
                                   max_seq_len=32, first_chunk=16))
    out = eng.run([(p, GEN) for p in prompts])
    assert eng.tick_widths == {1, 8, 16}
    # jumbo 16 + regular chunk 4 for the 20-prompt; the short prompt's
    # first grant is budget-clipped (18 - 16 = 2) so it takes two chunks
    assert eng.scheduler.n_prefill_chunks == 2 + 2
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(out["results"][rid], ref)


def test_engine_pallas_backend_parity(model, params_by_format):
    """The acceptance gate in-process: compressed weights served through
    the fused page-gather flash-decode kernel (interpret mode) with KV
    splits — per-token parity with sequential generate()."""
    params = params_by_format["bcsr"]
    prompts = _prompts([5, 12, 3], model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=3, prefill_chunk=8, page_size=4,
                                   max_seq_len=24, attn_backend="pallas",
                                   kv_splits=2))
    out = eng.run([(p, GEN) for p in prompts])
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(
            out["results"][rid], ref,
            err_msg=f"request {rid} (prompt_len={len(p)})")


# ---------------------------------------------------------------------------
# Request layer: prefix-cache hits + priority preemption (engine level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["dense", "bcsr", "palette8"])
@pytest.mark.parametrize("jumbo", [False, True])
def test_prefix_cache_hit_per_token_parity(model, params_by_format, fmt,
                                           jumbo):
    """The prefix-cache acceptance matrix: a cold wave populates the radix
    tree, a warm wave of requests sharing the 14-token prefix maps the
    cached pages (incl. a COW boundary page — 14 is not page-aligned) and
    every request still matches generate() token for token, across
    dense/BCSR/PaletteBCSR weights and chunked vs jumbo first prefill."""
    params = params_by_format[fmt]
    shared = _prompts([14], model.cfg.vocab, seed=23)[0]
    tails = _prompts([3, 2, 5], model.cfg.vocab, seed=29)
    prompts = [np.concatenate([shared, t]) for t in tails]
    eng = ServeEngine(model, params, EngineConfig(
        max_batch=2, prefill_chunk=8, page_size=4, max_seq_len=24,
        first_chunk=16 if jumbo else None, prefix_cache=True))
    out_cold = eng.run([(prompts[0], GEN)])
    assert eng.prefix_cache.tokens_hit == 0          # tree was empty
    out_warm = eng.run([(p, GEN) for p in prompts[1:]])
    # both warm requests hit at least the 3 fully shared pages (12 tokens)
    assert out_warm["stats"]["n_cached_tokens"] >= 2 * 12
    assert out_warm["stats"]["prefix_hit_rate"] > 0
    results = {**out_cold["results"], **out_warm["results"]}
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(
            results[rid], ref,
            err_msg=f"request {rid} ({fmt}, jumbo={jumbo})")
    widths = {1, 8} | ({16} if jumbo else set())
    assert eng.tick_widths <= widths                 # no extra step shape


def test_preempt_resume_per_token_parity(model, params_by_format):
    """A batch-class request is preempted mid-decode by an interactive
    arrival (capacity 1), its pages are freed, and on resume its prompt +
    generated tokens are re-prefilled — both requests still match the
    uninterrupted generate() run token for token. The prefix cache makes
    the resume cheap (the victim's own prompt pages survive in the tree)."""
    params = params_by_format["bcsr"]
    prompts = _prompts([9, 7], model.cfg.vocab, seed=31)
    eng = ServeEngine(model, params, EngineConfig(
        max_batch=1, prefill_chunk=8, page_size=4, max_seq_len=24,
        prefix_cache=True))
    finished = []
    eng.submit(prompts[0], 8, priority="batch")
    for _ in range(4):                               # batch starts decoding
        finished.extend(eng.step())
    eng.submit(prompts[1], 4, priority="interactive")
    while eng.scheduler.has_work():
        finished.extend(eng.step())
    recs = {r["rid"]: r for r in finished}
    assert eng.scheduler.n_preemptions >= 1
    assert recs[0]["n_preempted"] >= 1
    # the interactive request finished before the preempted batch one
    assert [r["rid"] for r in finished].index(1) < \
        [r["rid"] for r in finished].index(0)
    for rid, gen in ((0, 8), (1, 4)):
        ref = np.asarray(generate(model, params,
                                  prompts[rid][None, :], gen))[0]
        np.testing.assert_array_equal(recs[rid]["tokens"], ref,
                                      err_msg=f"request {rid}")
    # everything recycled: only the radix tree still owns pages
    tree = eng.prefix_cache.n_cached_pages
    assert eng.allocator.n_free == eng.config.total_pages - 1 - tree


def test_engine_per_class_stats_and_hit_rate():
    """run() stats carry the SLO accounting: by_class p50/p95 TTFT and
    latency keyed by priority, n_preemptions, prefix_hit_rate."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(2))
    prompts = _prompts([6, 6, 6], model.cfg.vocab)
    eng = ServeEngine(model, params, EngineConfig(
        max_batch=2, prefill_chunk=8, page_size=4, max_seq_len=16,
        prefix_cache=True))
    for i, pr in enumerate(["interactive", "standard", "batch"]):
        eng.submit(prompts[i], 3, priority=pr)
    finished = []
    while eng.scheduler.has_work():
        finished.extend(eng.step())
    stats = eng._stats(finished, 1.0)
    assert set(stats["by_class"]) == {0, 1, 2}
    for cs in stats["by_class"].values():
        assert cs["n_requests"] == 1
        assert cs["latency_p95_s"] >= cs["ttft_p50_s"] >= 0
    assert stats["n_preemptions"] == eng.scheduler.n_preemptions
    assert 0.0 <= stats["prefix_hit_rate"] <= 1.0
