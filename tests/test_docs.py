"""Docs stay runnable: every ```python snippet in README.md and docs/*.md
executes, and every intra-repo markdown link resolves.

This is the docs CI job (see .github/workflows/ci.yml); it also runs in
tier-1 so a doc-breaking refactor fails locally. Snippets must be
self-contained and fast — they are exec'd in-process with a fresh globals
dict (``pyproject.toml`` already puts ``src`` on the path).
"""
from __future__ import annotations

import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
DOCS = sorted([REPO / "README.md", *(REPO / "docs").glob("*.md")])

_FENCE = re.compile(r"```python\n(.*?)```", re.S)
# [text](target) — ignore images' inner brackets by matching lazily
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _snippets():
    out = []
    for doc in DOCS:
        for i, block in enumerate(_FENCE.findall(doc.read_text())):
            out.append(pytest.param(doc, block,
                                    id=f"{doc.name}-snippet{i}"))
    return out


def _links():
    out = []
    for doc in DOCS:
        for i, target in enumerate(_LINK.findall(doc.read_text())):
            out.append(pytest.param(doc, target, id=f"{doc.name}-link{i}"))
    return out


def test_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "architecture.md").exists()
    assert (REPO / "docs" / "size_accounting.md").exists()
    assert len(DOCS) >= 3


@pytest.mark.parametrize("doc,src", _snippets())
def test_python_snippet_runs(doc, src):
    exec(compile(src, f"<{doc.name} snippet>", "exec"),  # noqa: S102
         {"__name__": f"doc_snippet_{doc.stem}"})


@pytest.mark.parametrize("doc,target", _links())
def test_intra_repo_link_resolves(doc, target):
    if target.startswith(("http://", "https://", "mailto:")):
        pytest.skip("external link")
    path = target.split("#", 1)[0]
    if not path:
        pytest.skip("pure anchor")
    resolved = (doc.parent / path).resolve()
    assert resolved.exists(), f"{doc.name} links to missing {target}"
