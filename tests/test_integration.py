"""Integration tests: full SpC pipeline end-to-end, serving generation,
paper CNN training, sparse serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as metrics_lib
from repro.core.optimizers import prox_adam
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models.cnn import CNN_ZOO
from repro.models.model_zoo import build
from repro.models.layers import apply_mlp, init_mlp
from repro.serve.step import generate, make_prefill_step
from repro.sparse.formats import dense_to_bcsr
from repro.train.loop import run_spc_pipeline
from repro.train.step import make_train_step


def test_spc_pipeline_lm_end_to_end():
    """Paper pipeline on a reduced LM: loss falls, compression happens,
    debias keeps the mask and recovers loss."""
    model = build("smollm-360m", reduced=True, remat=False)
    cfg = model.cfg
    data = TokenStreamConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    params = model.init(jax.random.PRNGKey(0))

    def make_step(opt):
        return jax.jit(make_train_step(model, opt))

    state, hist, hist_db, report = run_spc_pipeline(
        params, make_step,
        opt_spc=prox_adam(3e-3, lam=2.0),
        opt_debias=prox_adam(3e-3, lam=0.0),
        batch_fn=lambda s: token_batch(data, s),
        spc_steps=40, debias_steps=15, log_every=10)

    assert hist[-1]["loss"] < hist[0]["loss"]
    assert report["spc"]["compression_rate"] > 0.3
    # debias must not change the zero pattern
    assert report["debias"]["nnz"] == report["spc"]["nnz"]
    # debias loss should not be worse than end of SpC by much
    assert hist_db[-1]["loss"] < hist[-1]["loss"] + 0.5


def test_generate_produces_tokens():
    model = build("smollm-360m", reduced=True, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                model.cfg.vocab)
    out = generate(model, params, prompt, steps=6)
    assert out.shape == (2, 6)
    assert int(jnp.max(out)) < model.cfg.vocab


def test_prefill_matches_last_position_logits():
    model = build("qwen3-0.6b", reduced=True, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              model.cfg.vocab)
    full, _ = jax.jit(model.apply_train)(params, {"inputs": toks})
    last, _ = jax.jit(make_prefill_step(model))(params, {"inputs": toks})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("name", ["lenet5", "resnet32-cifar"])
def test_cnn_trains(name):
    from benchmarks.common import data_for, evaluate_cnn, train_cnn
    model = CNN_ZOO[name]
    params, hist = train_cnn(model, prox_adam(1e-3, lam=0.0), steps=30,
                             eval_every=30, batch=32)
    assert np.isfinite(hist[-1]["loss"])


def test_sparse_serving_path_matches_dense():
    """apply_mlp with BCSR weights == dense apply (paper serving path)."""
    key = jax.random.PRNGKey(0)
    p = init_mlp(key, 64, 128, gated=True)
    # sparsify wi at block granularity
    wi = np.array(p["wi"])          # writable copy
    wi[:32, :] = 0.0
    p["wi"] = jnp.asarray(wi)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    dense = apply_mlp(p, x, "silu", True)
    sp = {"wi": dense_to_bcsr(wi.T, block=(32, 32))}   # stored (out, in)
    sparse = apply_mlp(p, x, "silu", True, sparse_weights=sp)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse),
                               atol=1e-4, rtol=1e-3)


def test_model_size_accounting():
    params = {"w": jnp.zeros((100, 100)).at[:10, :10].set(1.0)}
    from repro.core.metrics import model_size_bytes
    dense = model_size_bytes(params, sparse=False)
    sparse = model_size_bytes(params, sparse=True)
    assert dense == 100 * 100 * 4
    assert sparse == 100 * (4 + 4) + 101 * 4
