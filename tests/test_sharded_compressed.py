"""Sharded compressed runtime: ``param_shardings`` on BlockCSR/PaletteBCSR
leaves, and the end-to-end sharded-vs-single-device parity.

Rules under test (distributed/sharding.py):
  * index arrays (col_idx/row_ptr/gather tables) and palettes REPLICATE,
  * the block store (data/codes) shards along the slot axis — the
    block-row-major storage axis, i.e. the compressed analogue of the
    dense out-dim rule for that path — for every layout: 2D (head),
    layer-stacked, and MoE per-expert (L, E) stacks,
  * ``split_trainable`` reuses the same arrays, so shardings survive into
    the SpC-Retrain debias view (and its ``bcsr_data`` paths re-derive the
    same rule),
  * the pad_bcsr empty-layer edge (an all-zero slice) stays well-formed.

The in-process tests run on a (1, 1) mesh (axis size 1 keeps every
divisibility check true, so the *rule* is visible in the spec); the
subprocess test forces 8 host devices and checks real (2, 4) sharding plus
logits parity.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, mesh_from_flag
from repro.models.model_zoo import build
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   prune_blocks_for_plan, quantize_bcsr,
                                   split_trainable)
from repro.sparse.formats import dense_to_bcsr, pad_bcsr

PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


def _mesh11():
    return make_host_mesh(1, 1)


def _sparse_w(rows=7, shape=(64, 128), seed=0):
    """(out, in) matrix with ``rows`` nonzero 8-row block rows -> rows+1
    slots (pad slot 0 included)."""
    w = np.zeros(shape, np.float32)
    rng = np.random.default_rng(seed)
    for r in range(rows):
        w[r * 8:(r + 1) * 8, :64] = rng.normal(size=(8, 64))
    return w


def test_row_shard_2d_and_replicated_indices():
    mesh = _mesh11()
    m = dense_to_bcsr(_sparse_w(), (8, 64))
    sh = shd.param_shardings({"head": m}, mesh)["head"]
    assert sh.data.spec == P("model", None, None)   # vocab -> model
    for f in ("col_idx", "row_ptr", "gather_idx", "gather_blk",
              "gather_nnz", "gather_t_idx", "gather_t_blk", "gather_t_nnz"):
        assert getattr(sh, f).spec == P(), f


def test_row_shard_follows_dense_rule_per_path():
    mesh = _mesh11()
    m = dense_to_bcsr(_sparse_w(), (8, 64))
    for sub, name, axis in [("attn", "wq", "model"),   # heads
                            ("mlp", "wi", "model"),    # mlp
                            ("mlp", "wo", "data"),     # embed (FSDP)
                            ("tm", "rwkv_r", "model"),  # embed2
                            ("rec", "lru_in", "model"),  # lru
                            ("cm", "cm_v", "data")]:   # embed
        tree = {"rem": {"r0": {sub: {name: m}}}}
        sh = shd.param_shardings(tree, mesh)["rem"]["r0"][sub][name]
        assert sh.data.spec == P(axis, None, None), (sub, name,
                                                     sh.data.spec)


def test_row_shard_stacked_and_moe_layouts():
    mesh = _mesh11()
    m = dense_to_bcsr(_sparse_w(), (8, 64))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), m, m)       # (L=2, ...)
    moe = jax.tree.map(lambda *xs: jnp.stack(xs), stacked, stacked)  # (L, E)
    sh = shd.param_shardings(
        {"layers": {"b0": {"mlp": {"wi": stacked}}}}, mesh)
    spec = sh["layers"]["b0"]["mlp"]["wi"].data.spec
    assert spec == P(None, "model", None, None)  # slot axis 1, L repl
    sh = shd.param_shardings(
        {"layers": {"b0": {"moe": {"ewi": moe}}}}, mesh)
    spec = sh["layers"]["b0"]["moe"]["ewi"].data.spec
    assert spec == P(None, None, "model", None, None)  # (L, E, slots..)


def test_palette_codes_shard_palette_replicates():
    mesh = _mesh11()
    q = quantize_bcsr(dense_to_bcsr(_sparse_w(), (8, 64)), 8)
    sh = shd.param_shardings({"rem": {"r0": {"mlp": {"wi": q}}}}, mesh)
    sh = sh["rem"]["r0"]["mlp"]["wi"]
    assert sh.codes.spec == P("model", None, None)
    assert sh.palette.spec == P()
    assert sh.col_idx.spec == P()


def test_empty_layer_pad_bcsr_edge():
    """A fully-pruned slice (n_blocks == 0, only the pad slot) padded up
    alongside a non-empty slice still gets a well-formed sharding."""
    mesh = _mesh11()
    full = dense_to_bcsr(_sparse_w(), (8, 64))
    empty = dense_to_bcsr(np.zeros((64, 128), np.float32), (8, 64))
    empty = pad_bcsr(empty, full.data.shape[0], full.gather_idx.shape[1],
                     full.gather_t_idx.shape[1])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), full, empty)
    sh = shd.param_shardings({"layers": {"b0": {"mlp": {"wi": stacked}}}},
                             mesh)["layers"]["b0"]["mlp"]["wi"]
    assert sh.data.spec == P(None, "model", None, None)
    placed = jax.device_put(stacked, sh)
    np.testing.assert_array_equal(np.asarray(placed.data),
                                  np.asarray(stacked.data))


class _FakeMesh:
    """Only .shape is consulted by the spec assignment."""
    shape = {"data": 2, "model": 2}


def test_nondividing_slot_count_replicates():
    """Divisibility fallback: on a model=2 axis an odd slot count must
    replicate rather than error (same silent-replication rule as dense)."""
    m7 = dense_to_bcsr(_sparse_w(rows=6), (8, 64))   # 7 slots (odd)
    spec = shd._bcsr_row_spec("['head']", np.asarray(m7.data), _FakeMesh(),
                              shd.PARAM_RULES)
    assert all(s is None for s in tuple(spec)), spec
    m8 = dense_to_bcsr(_sparse_w(rows=7), (8, 64))   # 8 slots: shards
    spec = shd._bcsr_row_spec("['head']", np.asarray(m8.data), _FakeMesh(),
                              shd.PARAM_RULES)
    assert tuple(spec)[0] == "model", spec


def test_slot_multiple_packs_nondividing_layout_for_sharding():
    """plan.slot_multiple pads the slot axis to a mesh-divisible count, so a
    layout that previously replicated (odd slots on a model=2 axis) now
    shards — and the padded store is still value-identical."""
    import dataclasses

    w = _sparse_w(rows=6)                    # 7 slots: replicates on model=2
    plan = dataclasses.replace(PLAN, slot_multiple=4)
    cp = compress_params({"head": jnp.asarray(w.T)}, plan)   # stored (d, V)
    m = cp.sparse["head"]
    assert m.data.shape[0] == 8, m.data.shape
    spec = shd._bcsr_row_spec("['head']", np.asarray(m.data), _FakeMesh(),
                              shd.PARAM_RULES)
    assert tuple(spec)[0] == "model", spec
    # padding slots are zero blocks: the densified matrix is unchanged
    np.testing.assert_array_equal(np.asarray(m.to_dense()), w)


def test_slot_multiple_auto_resolves_from_active_mesh():
    """slot_multiple=None auto-packs to the lcm of the ambient mesh's axis
    sizes when compression runs under use_mesh (the SpC-Retrain pipeline
    compresses inside the mesh context), and stays a no-op without one."""
    w = _sparse_w(rows=6)                    # 7 slots unpacked
    cp = compress_params({"head": jnp.asarray(w.T)}, PLAN)
    assert cp.sparse["head"].data.shape[0] == 7
    with shd.use_mesh(_FakeMesh()):          # lcm(2, 2) = 2 -> pack to 8
        cp = compress_params({"head": jnp.asarray(w.T)}, PLAN)
    assert cp.sparse["head"].data.shape[0] == 8
    np.testing.assert_array_equal(np.asarray(cp.sparse["head"].to_dense()), w)


def test_split_trainable_preserves_shardings():
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)
    mesh = _mesh11()
    cp = jax.device_put(cp, shd.param_shardings(cp, mesh))
    trainable, rebuild = split_trainable(cp)
    for key, leaf in trainable["bcsr_data"].items():
        assert isinstance(leaf.sharding, jax.sharding.NamedSharding), key
        # the bcsr_data path re-derives the SAME rule param_shardings used
        resh = shd.param_shardings(trainable, mesh)["bcsr_data"][key]
        assert leaf.sharding.spec == resh.spec, key
    rebuilt = rebuild(trainable)
    flat_a = jax.tree.leaves(cp)
    flat_b = jax.tree.leaves(rebuilt)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_params_shardings_whole_tree():
    """param_shardings over a full CompressedParams: dense residue follows
    the dense rules, every BCSR leaf mirrors into per-field shardings."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)
    mesh = _mesh11()
    sh = shd.param_shardings(cp, mesh)
    placed = jax.device_put(cp, sh)            # structures must line up
    l0, _ = jax.jit(model.prefill)(
        cp, jnp.zeros((2, 4), jnp.int32), model.init_cache(2, 8))
    with shd.use_mesh(mesh):
        l1, _ = jax.jit(model.prefill)(
            placed, jnp.zeros((2, 4), jnp.int32), model.init_cache(2, 8))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               atol=1e-5, rtol=1e-5)


def test_mesh_from_flag():
    assert mesh_from_flag("none") is None
    m = mesh_from_flag("1,1")
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(SystemExit):
        mesh_from_flag("bogus")
    with pytest.raises(SystemExit):
        mesh_from_flag("64,64")                # more devices than exist


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.model_zoo import build
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   prune_blocks_for_plan, split_trainable)

mesh = make_host_mesh(2, 4)
PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)
model = build("olmoe-1b-7b", reduced=True)
params = model.init(jax.random.PRNGKey(0))
pruned = prune_blocks_for_plan(params, PLAN, 0.75)
cp = compress_params(pruned, PLAN)
shardings = shd.param_shardings(cp, mesh)
cps = jax.device_put(cp, shardings)

prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                            model.cfg.vocab)
l0, _ = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 16))
with shd.use_mesh(mesh):
    l1, _ = jax.jit(model.prefill)(cps, prompt, model.init_cache(2, 16))

tr, _ = split_trainable(cps)
ewi = tr["bcsr_data"]["layers/b0_attn/moe/ewi"]
print(json.dumps({
    "n_devices": jax.device_count(),
    "err": float(np.max(np.abs(np.asarray(l0) - np.asarray(l1)))),
    "ewi_spec": str(ewi.sharding.spec),
    "wq_index_repl": str(
        cps.sparse["layers"]["b0_attn"]["attn"]["wq"].col_idx.sharding.spec),
}))
"""


@pytest.mark.slow
def test_sharded_compressed_prefill_matches_single_device():
    """8 forced host devices, (2, 4) mesh: compressed prefill under the mesh
    must match the unsharded run (the CI multi-device job asserts the same
    through the CLIs at 1e-4)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["n_devices"] == 8
    assert result["err"] < 1e-4, result
    assert result["wq_index_repl"] == "PartitionSpec()"
