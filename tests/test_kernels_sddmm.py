"""SDDMM (masked BCSR weight gradient) kernel vs oracle + vs dense AD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsr_sddmm.ops import bsr_weight_grad, bsr_weight_grad_ref
from repro.sparse.formats import bcsr_to_dense, dense_to_bcsr


def _block_sparse(rng, n, k, block, density):
    br, bc = block
    w = np.zeros((n, k), np.float32)
    for i in range(n // br):
        for j in range(k // bc):
            if rng.random() < density:
                w[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = rng.normal(
                    size=block)
    return w


@pytest.mark.parametrize("n,k,block,density", [
    (64, 96, (32, 32), 0.4), (96, 64, (16, 16), 0.7),
    (64, 64, (8, 128), 1.0), (64, 64, (32, 32), 0.05),
])
def test_sddmm_matches_ref(n, k, block, density):
    rng = np.random.default_rng(hash((n, k, density)) % 2**31)
    w = _block_sparse(rng, n, k, block, density)
    m = dense_to_bcsr(w, block)
    x = jnp.asarray(rng.normal(size=(48, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(48, n)), jnp.float32)
    got = bsr_weight_grad(x, dy, m, bm=16)
    want = bsr_weight_grad_ref(x, dy, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


def test_sddmm_matches_dense_autodiff():
    """The masked block gradient equals dY^T X at the surviving blocks —
    i.e. the exact gradient of the mask-frozen (debias) retraining loss."""
    rng = np.random.default_rng(0)
    block = (16, 16)
    w = _block_sparse(rng, 64, 64, block, 0.5)
    m = dense_to_bcsr(w, block)
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    dy_target = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)

    def loss(w_dense):
        return 0.5 * jnp.sum((x @ w_dense.T - dy_target) ** 2)

    g_dense = jax.grad(loss)(jnp.asarray(w))
    dy = x @ jnp.asarray(w).T - dy_target          # dL/d(xW') for this loss
    got = bsr_weight_grad(x, dy, m, bm=16)

    # scatter block grads back to dense and compare on the mask
    mask = np.asarray(bcsr_to_dense(m)) != 0
    got_dense = np.zeros_like(w)
    rows, cols = np.nonzero(np.any(
        np.asarray(w).reshape(4, 16, 4, 16).transpose(0, 2, 1, 3), (2, 3)))
    for s, (r, c) in enumerate(zip(rows, cols), start=1):
        got_dense[r*16:(r+1)*16, c*16:(c+1)*16] = np.asarray(got[s])
    np.testing.assert_allclose(got_dense[mask], np.asarray(g_dense)[mask],
                               atol=1e-2, rtol=1e-4)


def test_sddmm_pad_slot_zero():
    rng = np.random.default_rng(1)
    w = _block_sparse(rng, 32, 32, (16, 16), 0.5)
    m = dense_to_bcsr(w, (16, 16))
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    got = bsr_weight_grad(x, dy, m, bm=16)
    assert np.all(np.asarray(got[0]) == 0)
