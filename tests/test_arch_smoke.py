"""Per-architecture smoke tests (assignment f): reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.optimizers import prox_adam
from repro.models import frontends
from repro.models.model_zoo import build
from repro.train.state import TrainState
from repro.train.step import make_train_step


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.frontend != "none":
        emb = frontends.synthetic_embeddings(key, cfg, b, s)
        return {"inputs": emb, "labels": toks}
    return {"inputs": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    model = build(arch, reduced=True, remat=False)
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, aux = jax.jit(model.apply_train)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux["load_balance"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    model = build(arch, reduced=True, remat=False)
    cfg = model.cfg
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt = prox_adam(1e-3, lam=0.01)
    state = TrainState.create(params, opt)
    step = jax.jit(make_train_step(model, opt))
    state, metrics = step(state, _batch(cfg, key))
    assert np.isfinite(metrics["loss"])
    assert np.isfinite(metrics["grad_norm"])
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    model = build(arch, reduced=True, remat=False)
    cfg = model.cfg
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    b, s = 2, 16
    cache = model.init_cache(b, s)
    if cfg.frontend != "none":
        tok = frontends.synthetic_embeddings(key, cfg, b, 1)
    else:
        tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, cache2 = jax.jit(model.decode_step)(params, tok, cache,
                                                jnp.int32(0))
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen3-0.6b",
                                  "recurrentgemma-9b", "rwkv6-3b",
                                  "musicgen-medium"])
def test_decode_matches_train_forward(arch):
    """Teacher-forced decode reproduces the train forward logits."""
    model = build(arch, reduced=True, remat=False)
    cfg = model.cfg
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    b, s = 2, 12
    if cfg.frontend != "none":
        inputs = frontends.synthetic_embeddings(key, cfg, b, s)
    else:
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab)
    ref, _ = jax.jit(model.apply_train)(params, {"inputs": inputs})
    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    for t in range(s):
        tok = inputs[:, t:t + 1]
        lg, cache = step(params, tok, cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(ref[:, t]),
                                   atol=5e-4, rtol=1e-3)


def test_config_param_counts_reasonable():
    """Analytic n_params within 20% of the spec'd sizes."""
    expected = {"command-r-plus-104b": 104e9, "minitron-8b": 8e9,
                "smollm-360m": 0.36e9, "qwen3-0.6b": 0.6e9,
                "recurrentgemma-9b": 9e9, "rwkv6-3b": 3e9}
    for arch, want in expected.items():
        got = get_config(arch).n_params()
        assert abs(got - want) / want < 0.2, (arch, got, want)


def test_reduced_configs_are_small():
    for arch in ARCH_IDS:
        model = build(arch, reduced=True, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        n = sum(p.size for p in jax.tree.leaves(params))
        assert n < 1_000_000, (arch, n)
