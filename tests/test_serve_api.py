"""The typed request API (serve/api.py): the single serving contract.

Covers JSON round-trips for ``SamplingParams`` / ``Request`` /
``StreamEvent`` / ``Completion`` (property sweeps under hypothesis when
installed, seeded parametrized fallbacks otherwise), actionable
validation errors (unknown key did-you-mean, bad priority type), the
request-file schema (``prompt_len`` / ``gen`` conveniences), the
``merge_legacy_sampling`` deprecation shim, ``EngineConfig`` as the
router's serializable replica spec, and new-API-vs-legacy-kwargs parity
on the sampler path.
"""
import warnings

import numpy as np
import pytest

from repro.serve import api
from repro.serve.api import (ApiValidationError, Completion, Request,
                             SamplingParams, StreamEvent,
                             merge_legacy_sampling, normalize_request_entry,
                             parse_request_file, resolve_priority)

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _seeded_requests(n=12):
    """Random Request values mirroring the hypothesis strategy."""
    out = []
    for seed in range(n):
        rng = np.random.default_rng(seed)
        sampling = None
        if seed % 3 == 0:
            sampling = SamplingParams(
                temperature=float(rng.uniform(0, 2)),
                top_k=int(rng.integers(0, 50)),
                top_p=float(rng.uniform(0.1, 1.0)))
        out.append(Request(
            prompt=rng.integers(0, 1000, size=rng.integers(1, 20)).tolist(),
            max_new_tokens=int(rng.integers(1, 100)),
            eos_id=int(rng.integers(0, 1000)) if seed % 2 else None,
            priority=int(rng.integers(0, 4)),
            sampling=sampling,
            request_id=int(rng.integers(0, 100)) if seed % 4 == 0 else None))
    return out


# -- SamplingParams ---------------------------------------------------------

def test_sampling_defaults_are_greedy():
    sp = SamplingParams()
    assert sp.greedy and sp.temperature == 0.0 and sp.top_k == 0 \
        and sp.top_p == 1.0
    assert not SamplingParams(temperature=0.5).greedy


def test_sampling_roundtrip():
    sp = SamplingParams(temperature=0.7, top_k=40, top_p=0.9)
    assert SamplingParams.from_json(sp.to_json()) == sp


@pytest.mark.parametrize("kw", [
    {"temperature": -0.1}, {"top_k": -1}, {"top_k": 1.5},
    {"top_p": 0.0}, {"top_p": 1.5},
])
def test_sampling_validation(kw):
    with pytest.raises(ApiValidationError):
        SamplingParams(**kw)


def test_sampling_from_json_rejects_unknown_key():
    with pytest.raises(ApiValidationError, match="did you mean 'top_k'"):
        SamplingParams.from_json({"topk": 5})


def test_merge_legacy_sampling_warns_once_per_site():
    api._warned.discard("test.site")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sp = merge_legacy_sampling(None, "test.site", temperature=0.5)
        merge_legacy_sampling(None, "test.site", temperature=0.5)
    assert sp == SamplingParams(temperature=0.5)
    assert len([x for x in w if issubclass(x.category,
                                           DeprecationWarning)]) == 1


def test_merge_legacy_sampling_rejects_both():
    with pytest.raises(ApiValidationError, match="both"):
        merge_legacy_sampling(SamplingParams(), "test.site2", top_k=3)


def test_merge_legacy_sampling_passthrough():
    sp = SamplingParams(temperature=0.3)
    assert merge_legacy_sampling(sp, "test.site3") is sp
    assert merge_legacy_sampling(None, "test.site3") == SamplingParams()


# -- Request ----------------------------------------------------------------

def test_request_normalizes_prompt_and_priority():
    r = Request(prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=4,
                priority="interactive")
    assert r.prompt == (1, 2, 3)
    assert r.priority == 0
    assert r.prompt_ids.dtype == np.int32


def test_request_roundtrip_defaults_omitted():
    r = Request(prompt=[1, 2], max_new_tokens=8)
    d = r.to_json()
    assert set(d) == {"prompt", "max_new_tokens"}   # defaults omitted
    assert Request.from_json(d) == r


@pytest.mark.parametrize("idx", range(12))
def test_request_roundtrip_seeded(idx):
    r = _seeded_requests()[idx]
    assert Request.from_json(r.to_json()) == r


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        st.lists(st.integers(0, 10_000), min_size=1, max_size=64),
        st.integers(1, 1000), st.none() | st.integers(0, 10_000),
        st.integers(0, 5) | st.sampled_from(
            sorted(api.PRIORITY_CLASSES)),
        st.none() | st.builds(
            SamplingParams,
            temperature=st.floats(0, 4, allow_nan=False, width=32),
            top_k=st.integers(0, 100),
            top_p=st.floats(0.01, 1.0, allow_nan=False, width=32)))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_request_roundtrip_property(prompt, gen, eos, priority,
                                        sampling):
        r = Request(prompt=prompt, max_new_tokens=gen, eos_id=eos,
                    priority=priority, sampling=sampling)
        rt = Request.from_json(r.to_json())
        assert rt == r
        assert rt.priority == resolve_priority(priority)

    @hypothesis.given(st.builds(
        SamplingParams,
        temperature=st.floats(0, 4, allow_nan=False, width=32),
        top_k=st.integers(0, 100),
        top_p=st.floats(0.01, 1.0, allow_nan=False, width=32)))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_sampling_roundtrip_property(sp):
        assert SamplingParams.from_json(sp.to_json()) == sp


@pytest.mark.parametrize("d,match", [
    ({"max_new_tokens": 3}, "missing required key 'prompt'"),
    ({"prompt": [1]}, "missing required key 'max_new_tokens'"),
    ({"prompt": [1], "max_new_tokens": 3, "promt": 1}, "did you mean"),
    ({"prompt": [], "max_new_tokens": 3}, "non-empty"),
    ({"prompt": [1], "max_new_tokens": 0}, "max_new_tokens"),
    ({"prompt": [1], "max_new_tokens": 3, "priority": True}, "priority"),
    ({"prompt": [1], "max_new_tokens": 3, "priority": "urgent"},
     "unknown priority class"),
])
def test_request_validation_is_actionable(d, match):
    with pytest.raises(ApiValidationError, match=match):
        Request.from_json(d)


# -- StreamEvent / Completion -----------------------------------------------

def test_stream_event_roundtrip():
    ev = StreamEvent(request_id=3, token=17, index=0, done=False)
    assert StreamEvent.from_json(ev.to_json()) == ev
    ev2 = StreamEvent(request_id=3, token=17, index=5, done=True, replica=1)
    assert StreamEvent.from_json(ev2.to_json()) == ev2


def test_completion_roundtrip_and_derived():
    c = Completion(request_id=1, tokens=(5, 6, 7), n_prompt=4, priority=2,
                   n_cached=2, n_preempted=1, n_redispatched=1, replica=0,
                   t_submit=10.0, t_first=10.5, t_done=12.0)
    assert Completion.from_json(c.to_json()) == c
    assert c.n_generated == 3
    assert c.ttft_s == pytest.approx(0.5)
    assert c.latency_s == pytest.approx(2.0)
    assert c.token_ids.dtype == np.int32
    assert Completion(request_id=0, tokens=(), n_prompt=1).ttft_s is None


def test_completion_from_record():
    rec = {"rid": 7, "slot": 0, "tokens": [np.int32(3), np.int32(4)],
           "n_prompt": 5, "n_generated": 2, "priority": 1, "n_cached": 3,
           "n_preempted": 0, "t_submit": 1.0, "t_admit": 1.1,
           "t_first": 1.2, "t_done": 2.0}
    c = Completion.from_record(rec, replica=1)
    assert c.request_id == 7 and c.tokens == (3, 4) and c.replica == 1
    assert c.n_cached == 3 and c.t_first == 1.2


# -- request files ----------------------------------------------------------

def test_request_file_conveniences():
    entries = parse_request_file(
        [{"prompt_len": 16, "gen": 8},
         {"prompt": [1, 2, 3]},
         {"prompt_len": 4, "max_new_tokens": 2, "priority": "batch",
          "sampling": {"temperature": 0.5}}],
        default_gen=32, default_priority="standard")
    assert entries[0]["prompt_len"] == 16
    assert entries[0]["max_new_tokens"] == 8
    assert entries[1]["prompt"] == [1, 2, 3]
    assert entries[1]["max_new_tokens"] == 32        # default_gen
    assert entries[1]["priority"] == 1
    assert entries[2]["priority"] == 2
    assert entries[2]["sampling"] == SamplingParams(temperature=0.5)


@pytest.mark.parametrize("spec,match", [
    ({"not": "a list"}, "JSON list"),
    ([], "empty"),
    ([{"prompt_len": 4, "gen": 2, "max_new_tokens": 2}], "one, not both"),
    ([{"gen": 2}], "exactly one of 'prompt'"),
    ([{"prompt": [1], "prompt_len": 4}], "exactly one of 'prompt'"),
    ([{"prompt_len": 4, "gen": "two"}], "must be an int"),
    ([{"prompt_len": 16}, {"promt_len": 16}],
     r"requests\[1\].*did you mean 'prompt_len'"),
])
def test_request_file_validation(spec, match):
    with pytest.raises(ApiValidationError, match=match):
        parse_request_file(spec, default_gen=8)


def test_normalize_entry_indexes_errors():
    with pytest.raises(ApiValidationError, match=r"requests\[3\]"):
        normalize_request_entry("nope", 3, default_gen=8)


# -- EngineConfig: the router's replica spec --------------------------------

def test_engine_config_roundtrip():
    from repro.serve.engine import EngineConfig
    cfg = EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                       max_seq_len=64, prefix_cache=True,
                       class_shares=((0, 1.0), (2, 0.25)),
                       sampling=SamplingParams(temperature=0.5, top_k=10))
    rt = EngineConfig.from_json(cfg.to_json())
    assert rt == cfg
    assert rt.sampling == cfg.sampling
    # defaults are omitted from the wire form
    assert "attn_backend" not in cfg.to_json()
    with pytest.raises(ApiValidationError, match="did you mean"):
        EngineConfig.from_json({"max_batc": 4})


def test_engine_config_legacy_sampling_folds():
    from repro.serve.engine import EngineConfig
    api._warned.discard("serve.engine.EngineConfig")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = EngineConfig(max_batch=2, temperature=0.7, top_k=5)
    assert cfg.sampling == SamplingParams(temperature=0.7, top_k=5)
    assert cfg.temperature is None and cfg.top_k is None
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_scheduler_reexports_priority_api():
    # back-compat: the scheduler re-exports the priority vocabulary
    from repro.serve.scheduler import PRIORITY_CLASSES as SCHED_PC
    from repro.serve.scheduler import resolve_priority as sched_rp
    assert SCHED_PC is api.PRIORITY_CLASSES
    assert sched_rp is resolve_priority


# -- new-API vs legacy-kwargs parity (sampler path) -------------------------

def test_make_sampler_new_vs_legacy_parity():
    import jax
    from repro.serve.step import make_sampler

    logits = np.asarray(np.random.default_rng(0).normal(size=(3, 50)),
                        np.float32)
    rng = jax.random.PRNGKey(7)
    sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.7)
    new = make_sampler(sp)(logits, rng)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = make_sampler(temperature=0.8, top_k=12, top_p=0.7)(logits,
                                                                    rng)
        positional = make_sampler(0.8, 12, 0.7)(logits, rng)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(legacy))
    np.testing.assert_array_equal(np.asarray(new), np.asarray(positional))


def test_generate_new_vs_legacy_parity():
    """generate(sampling=SamplingParams(...)) == legacy kwargs spelling,
    token for token, on a tiny transformer."""
    import jax
    from repro.models.model_zoo import build
    from repro.serve.step import generate

    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0,
                           model.cfg.vocab), np.int32)
    sp = SamplingParams(temperature=0.9, top_k=8)
    rng = jax.random.PRNGKey(11)
    new = np.asarray(generate(model, params, prompt, 4, sampling=sp,
                              rng=rng))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = np.asarray(generate(model, params, prompt, 4,
                                     temperature=0.9, top_k=8, rng=rng))
    np.testing.assert_array_equal(new, legacy)
