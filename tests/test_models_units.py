"""Unit tests for model building blocks: chunked attention vs naive,
RG-LRU scan vs step recurrence, RWKV chunked vs stepwise, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import rglru, rwkv6
from repro.models.attention import chunked_attention
from repro.models.layers import apply_rope


def naive_attention(q, k, v, causal=True, window=None):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) * hd ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(b, s, h, hd)


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("window", [None, 8])
def test_chunked_attention_matches_naive(h, kv, window):
    rng = np.random.default_rng(0)
    b, s, hd = 2, 32, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=8, kv_chunk=8)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_chunked_attention_grads_finite():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 8)), jnp.float32)
    g = jax.grad(lambda q_: jnp.sum(
        chunked_attention(q_, k, v, q_chunk=8, kv_chunk=8) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i), 10000.0)
        kj = apply_rope(k, jnp.full((1, 1), j), 10000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def test_rglru_scan_matches_stepwise():
    cfg = get_config("recurrentgemma-9b").reduced()
    key = jax.random.PRNGKey(0)
    p = rglru.init_rglru(key, cfg)
    rng = np.random.default_rng(3)
    w = cfg.lru_width
    u = jnp.asarray(rng.normal(size=(2, 10, w)), jnp.float32)
    h_scan, h_last = rglru.rglru_scan(p, u)
    h = jnp.zeros((2, w), jnp.float32)
    for t in range(10):
        out, h = rglru.rglru_step(p, u[:, t:t + 1], h)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(h_scan[:, t]),
                                   atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last), atol=1e-5)


def test_rglru_scan_with_initial_state():
    cfg = get_config("recurrentgemma-9b").reduced()
    p = rglru.init_rglru(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    w = cfg.lru_width
    u = jnp.asarray(rng.normal(size=(1, 8, w)), jnp.float32)
    # split sequence: scan(first half) -> state -> scan(second half)
    h_all, _ = rglru.rglru_scan(p, u)
    h_1, last1 = rglru.rglru_scan(p, u[:, :4])
    h_2, _ = rglru.rglru_scan(p, u[:, 4:], h0=last1)
    np.testing.assert_allclose(np.asarray(h_2), np.asarray(h_all[:, 4:]),
                               atol=1e-5, rtol=1e-4)


def test_rglru_decay_bounded():
    """|a_t| < 1 always: the recurrence is contractive (stability)."""
    cfg = get_config("recurrentgemma-9b").reduced()
    p = rglru.init_rglru(jax.random.PRNGKey(0), cfg)
    u = jnp.asarray(np.random.default_rng(5).normal(size=(1, 4, cfg.lru_width))
                    * 10, jnp.float32)
    a, _ = rglru._rglru_coeffs(p, u)
    assert float(jnp.max(a)) < 1.0
    assert float(jnp.min(a)) > 0.0


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------

def test_rwkv_chunked_matches_stepwise():
    rng = np.random.default_rng(6)
    b, s, h, hd = 2, 16, 2, 8
    r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3))
    logw = jnp.asarray(-np.abs(rng.normal(size=(b, s, h, hd))) * 0.1,
                       jnp.float32)
    u = jnp.asarray(np.abs(rng.normal(size=(h, hd))), jnp.float32)

    o_chunk, s_chunk = rwkv6.chunked_wkv(r, k, v, logw, u, None, chunk=4)
    state = jnp.zeros((b, h, hd, hd), jnp.float32)
    for t in range(s):
        o_t, state = rwkv6.wkv_step(r[:, t:t + 1], k[:, t:t + 1],
                                    v[:, t:t + 1], logw[:, t:t + 1], u, state)
        np.testing.assert_allclose(np.asarray(o_t[:, 0]),
                                   np.asarray(o_chunk[:, t]),
                                   atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_chunk),
                               atol=1e-4, rtol=1e-3)


def test_rwkv_chunk_invariance():
    """Output independent of chunk size (chunk math correctness)."""
    rng = np.random.default_rng(7)
    b, s, h, hd = 1, 24, 2, 4
    r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3))
    logw = jnp.asarray(-np.abs(rng.normal(size=(b, s, h, hd))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32)
    o1, s1 = rwkv6.chunked_wkv(r, k, v, logw, u, None, chunk=4)
    o2, s2 = rwkv6.chunked_wkv(r, k, v, logw, u, None, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_no_drop_equals_dense_mixture():
    """With capacity >= worst case, MoE output == explicit expert mixture."""
    from repro.models import moe as moe_lib
    cfg = get_config("olmoe-1b-7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.n_experts)))
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y, aux = moe_lib.apply_moe(p, x, cfg)

    # explicit dense mixture
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    y_ref = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(idx[t, j])
            h = (jax.nn.silu(xt[t] @ p["ewg"][e]) * (xt[t] @ p["ewi"][e]))
            y_ref[t] += float(gate[t, j]) * np.asarray(h @ p["ewo"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               y_ref, atol=1e-3, rtol=1e-3)
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_moe_capacity_drops_tokens():
    from repro.models import moe as moe_lib
    cfg = get_config("olmoe-1b-7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(9).normal(size=(2, 32, cfg.d_model)),
                    jnp.float32)
    y, _ = moe_lib.apply_moe(p, x, cfg)   # must not crash; some tokens -> 0
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------

def test_int8_kv_cache_decode_close_to_fp():
    """Quantized-cache decode tracks the full-precision decode closely and
    halves the cache bytes."""
    import dataclasses as _dc
    from repro.models.model_zoo import build

    base = get_config("qwen3-0.6b").reduced()
    m_fp = build(base, remat=False)
    m_q = build(_dc.replace(base, kv_cache_dtype="int8"), remat=False)
    key = jax.random.PRNGKey(0)
    params = m_fp.init(key)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s), 0, base.vocab)
    c_fp = m_fp.init_cache(b, s)
    c_q = m_q.init_cache(b, s)
    bytes_fp = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c_fp))
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_q))
    assert bytes_q < 0.75 * bytes_fp

    step_fp = jax.jit(m_fp.decode_step)
    step_q = jax.jit(m_q.decode_step)
    for t in range(s):
        lf, c_fp = step_fp(params, toks[:, t:t + 1], c_fp, jnp.int32(t))
        lq, c_q = step_q(params, toks[:, t:t + 1], c_q, jnp.int32(t))
        # compare top-1 predictions + logit closeness
        pf = jax.nn.log_softmax(lf[:, 0].astype(jnp.float32))
        pq = jax.nn.log_softmax(lq[:, 0].astype(jnp.float32))
        assert float(jnp.max(jnp.abs(pf - pq))) < 0.15
