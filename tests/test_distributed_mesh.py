"""Small-mesh SPMD integration: runs a subprocess with 8 forced host devices
(the device count is locked at first jax init, so these tests cannot share
the main pytest process, which must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.optimizers import prox_adam
from repro.distributed import sharding as shd
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models.model_zoo import build
from repro.train.state import TrainState
from repro.train.step import make_train_step

from repro.launch.mesh import _axis_type_kwargs
mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_type_kwargs(2))
model = build("qwen3-0.6b", reduced=True, remat=False)
cfg = model.cfg
opt = prox_adam(1e-3, lam=0.5)
data = TokenStreamConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

with shd.use_mesh(mesh):
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState.create(params, opt)
    state_shd = shd.param_shardings(state, mesh)
    state = jax.device_put(state, state_shd)
    step = jax.jit(make_train_step(model, opt),
                   in_shardings=(state_shd, None),
                   out_shardings=(state_shd, None))
    losses = []
    for s in range(8):
        state, m = step(state, token_batch(data, s))
        losses.append(float(m["loss"]))

# single-device reference trajectory (same seeds): SPMD must match math
model2 = build("qwen3-0.6b", reduced=True, remat=False)
params2 = model2.init(jax.random.PRNGKey(0))
state2 = TrainState.create(params2, opt)
step2 = jax.jit(make_train_step(model2, opt))
losses2 = []
for s in range(8):
    state2, m2 = step2(state2, token_batch(data, s))
    losses2.append(float(m2["loss"]))

err = max(abs(a - b) for a, b in zip(losses, losses2))
w_sharded = np.asarray(jax.device_get(
    state.params["layers"]["b0_attn"]["mlp"]["wi"]))
w_single = np.asarray(state2.params["layers"]["b0_attn"]["mlp"]["wi"])
print(json.dumps({
    "loss_err": err,
    "param_err": float(np.max(np.abs(w_sharded - w_single))),
    "losses": losses,
    "n_devices": jax.device_count(),
}))
"""


@pytest.mark.slow
def test_spmd_training_matches_single_device(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["n_devices"] == 8
    assert result["loss_err"] < 2e-2, result
    assert result["param_err"] < 2e-2, result
    # training is actually progressing
    assert result["losses"][-1] < result["losses"][0]


_MOE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import moe as moe_lib

from repro.launch.mesh import _axis_type_kwargs
mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_type_kwargs(2))
cfg = get_config("olmoe-1b-7b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=float(cfg.moe.n_experts)))  # no-drop: exact
p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

y_ref, aux_ref = jax.jit(
    lambda p, x: moe_lib.apply_moe(p, x, cfg, impl="gspmd"))(p, x)
with shd.use_mesh(mesh):
    y_sm, aux_sm = jax.jit(
        lambda p, x: moe_lib.apply_moe(p, x, cfg, impl="shard_map"))(p, x)

def loss(p, impl):
    with shd.use_mesh(mesh if impl == "shard_map" else None):
        y, aux = moe_lib.apply_moe(p, x, cfg, impl=impl)
    return jnp.sum(y ** 2) + aux["load_balance"]

g1 = jax.grad(lambda p: loss(p, "gspmd"))(p)
g2 = jax.grad(lambda p: loss(p, "shard_map"))(p)
rel = max(
    float(jnp.max(jnp.abs(a - b)) / jnp.maximum(jnp.max(jnp.abs(a)), 1e-9))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print(json.dumps({
    "y_err": float(jnp.max(jnp.abs(y_ref - y_sm))),
    "lb_err": abs(float(aux_ref["load_balance"])
                  - float(aux_sm["load_balance"])),
    "grad_rel_err": rel,
}))
"""


@pytest.mark.slow
def test_shard_map_moe_matches_gspmd():
    """Expert-parallel shard_map MoE == single-program GSPMD MoE (values,
    aux losses, grads) under a no-drop capacity."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", _MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["y_err"] < 1e-4, result
    assert result["lb_err"] < 1e-5, result
    assert result["grad_rel_err"] < 1e-5, result
