"""End-to-end system behaviour: the full paper pipeline via the public
launchers, and dry-run cell coverage accounting."""
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config, shapes_for


def test_paper_pipeline_via_launcher(tmp_path):
    from repro.launch.train import main as train_main
    state, hist, hist_db, report = train_main([
        "--arch", "smollm-360m", "--reduced", "--steps", "30",
        "--debias-steps", "10", "--compress", "l1:2.0", "--lr", "3e-3",
        "--log-every", "10", "--ckpt-dir", str(tmp_path)])
    assert report["spc"]["compression_rate"] > 0.2
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert os.listdir(str(tmp_path))      # checkpoints written


def test_serve_launcher(tmp_path):
    from repro.launch.serve import main as serve_main
    out = serve_main(["--arch", "smollm-360m", "--reduced", "--batch", "2",
                      "--prompt-len", "4", "--gen", "6", "--sparse"])
    assert out.shape == (2, 6)


def test_cell_coverage_definition():
    """40 assigned cells: 32 runnable + 8 documented long_500k skips."""
    runnable = sum(len(shapes_for(get_config(a))) for a in ARCH_IDS)
    assert runnable == 32
    skipped = sum(1 for a in ARCH_IDS
                  if not get_config(a).sub_quadratic)
    assert skipped == 8
    assert runnable + skipped == 40


@pytest.mark.skipif(not os.path.isdir("experiments/dryrun"),
                    reason="dry-run artifacts not present")
def test_dryrun_artifacts_all_ok():
    import glob
    cells = glob.glob("experiments/dryrun/*.json")
    assert len(cells) >= 64
    for path in cells:
        r = json.load(open(path))
        assert r.get("ok"), f"{r['cell']}: {r.get('error')}"
        if r["mesh"] == "multi":
            assert r["chips"] == 512
        roof = r["roofline"]
        assert roof["flops_per_device"] > 0
        assert roof["dominant"] in ("compute", "memory", "collective")
