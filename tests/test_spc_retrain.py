"""SpC-Retrain: training directly into BlockCSR.

Covers the compressed-training tentpole:
  * the plan-aligned group-l1 prox shrinks exactly the (out, in) blocks
    ``compress_params`` tiles, across stored layouts (2D / attn 3D / stacked),
  * ``sparse_matmul``'s custom VJP: dw equals the densified autodiff oracle
    at resident slots across block sizes, odd (non-multiple) shapes and
    stacked layers — and the jaxpr contains NO dense (out, in) intermediate,
  * backend dispatch symmetry: 'pallas' and 'ref' agree on forward and both
    gradients (sparse_matmul / sparse_matmul_t share the 'auto' resolution),
  * mask-frozen debias retraining from a ``CompressedParams``: only
    BlockCSR.data moves, and debiased compressed logits match the densified
    (mask-frozen) reference to 1e-4,
  * zero-slot regression: all-zero / fully-pruned layers compress to valid
    empty BCSRs that serve, checkpoint, stack and backprop (zero grads).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import masks as masks_lib
from repro.core.optimizers import prox_adam
from repro.core.prox import prox_group_l1_blocks
from repro.models.model_zoo import build
from repro.sparse import ops as sparse_ops
from repro.sparse.compress import (CompressedParams, CompressionPlan,
                                   _as_out_in, compress_params,
                                   densify_compressed, iter_bcsr,
                                   make_plan_prox, prune_blocks_for_plan,
                                   split_trainable)
from repro.sparse.formats import bcsr_to_dense, dense_to_bcsr, pad_bcsr
from repro.train.state import TrainState
from repro.train.step import make_train_step

PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


def _block_sparse(rng, n, k, block, density):
    br, bc = block
    w = np.zeros((n, k), np.float32)
    for i in range(-(-n // br)):
        for j in range(-(-k // bc)):
            if rng.random() < density:
                w[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = rng.normal(
                    size=(min(br, n - i * br), min(bc, k - j * bc)))
    return w


# ---------------------------------------------------------------------------
# Plan-aligned prox
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path,shape", [
    ("['rem']['r0_attn']['mlp']['wi']", (64, 128)),        # 2D (in, out)
    ("['rem']['r0_attn']['attn']['wq']", (64, 4, 16)),     # (d, h, hd)
    ("['rem']['r0_attn']['attn']['wo']", (4, 16, 64)),     # (h, hd, d)
    ("['head']", (64, 128)),
])
def test_plan_prox_matches_out_in_group_l1(path, shape):
    """prox on the stored layout == group-l1 on the (out, in) view with the
    plan's block — the grid compress_params uses, so zeros line up."""
    plan = CompressionPlan(block=(8, 32), min_sparsity=0.3, min_size=512)
    prox = make_plan_prox(plan)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    tau = 16.0
    got = prox(z, tau, path=path)

    slash = path.replace("']['", "/").strip("[']")
    view = _as_out_in(slash, np.asarray(z))
    want_view = prox_group_l1_blocks(jnp.asarray(view), tau, block=(8, 32))
    got_view = _as_out_in(slash, np.asarray(got))
    np.testing.assert_allclose(got_view, np.asarray(want_view),
                               atol=1e-6, rtol=1e-6)
    # must produce whole zero blocks on that grid
    m = dense_to_bcsr(np.asarray(got_view), (8, 32))
    grid = int(np.prod(m.block_grid))
    assert m.n_blocks < grid, "no block hit exact zero"


def test_plan_prox_stacked_layers_and_fallback():
    plan = CompressionPlan(block=(8, 32), min_sparsity=0.3, min_size=512)
    prox = make_plan_prox(plan)
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.normal(size=(3, 64, 4, 16)).astype(np.float32))
    got = prox(z, 16.0, path="['layers']['b0_attn']['attn']['wq']")
    for layer in range(3):
        want = prox(z[layer], 16.0, path="['rem']['r0_attn']['attn']['wq']")
        np.testing.assert_allclose(np.asarray(got[layer]), np.asarray(want))
    # non-eligible leaves are left untouched: the group-l1 lambda is block-
    # norm-scaled, so an elementwise fallback would wipe out the (tied)
    # embedding in one step
    e = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(prox(e, 0.5, path="['embed']['embedding']")), np.asarray(e))


def test_spc_training_compresses_without_prune_step():
    """A few prox-opt steps with the plan prox must yield BCSR entries from
    compress_params directly — no pruning pass in between."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)
    opt = prox_adam(3e-3, lam=100.0, prox_fn=make_plan_prox(plan))
    state = TrainState.create(params, opt)
    step = jax.jit(make_train_step(model, opt))
    batch = {"inputs": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    for _ in range(12):
        state, _ = step(state, batch)
    cp = compress_params(state.params, plan)
    assert cp.sparse, "group-l1 training produced no compressible layer"


# ---------------------------------------------------------------------------
# sparse_matmul custom VJP: dw via SDDMM
# ---------------------------------------------------------------------------

def _dw_against_oracle(n, k, block, density, m_rows, backend):
    rng = np.random.default_rng(hash((n, k, block, m_rows)) % 2**31)
    w = _block_sparse(rng, n, k, block, density)
    mat = dense_to_bcsr(w, block)
    x = jnp.asarray(rng.normal(size=(m_rows, k)), jnp.float32)
    t = jnp.asarray(rng.normal(size=(m_rows, n)), jnp.float32)

    def loss(data):
        y = sparse_ops.sparse_matmul(
            x, dataclasses.replace(mat, data=data), backend=backend)
        return 0.5 * jnp.sum((y - t) ** 2)

    gd = jax.jit(jax.grad(loss))(mat.data)

    wd = jnp.asarray(np.pad(w, ((0, (-n) % block[0]), (0, (-k) % block[1]))))
    xp = jnp.pad(x, ((0, 0), (0, wd.shape[1] - k)))

    def dense_loss(wd):
        return 0.5 * jnp.sum(((xp @ wd.T)[:, :n] - t) ** 2)

    ogw = np.asarray(jax.grad(dense_loss)(wd))
    br, bc = block
    rows, cols = np.nonzero(np.any(
        np.asarray(bcsr_to_dense(mat)).reshape(
            mat.block_grid[0], br, mat.block_grid[1], bc
        ).transpose(0, 2, 1, 3) != 0, (2, 3)))
    assert np.all(np.asarray(mat.data[0]) == 0)
    got = np.asarray(gd)
    for s, (r, c) in enumerate(zip(rows, cols), start=1):
        np.testing.assert_allclose(
            got[s], ogw[r * br:(r + 1) * br, c * bc:(c + 1) * bc],
            atol=1e-3, rtol=1e-4)
    np.testing.assert_array_equal(got[0], 0)


@pytest.mark.parametrize("n,k,block,m_rows", [
    (64, 96, (16, 16), 32),
    (64, 64, (8, 64), 48),
    (96, 64, (32, 32), 16),
    (60, 90, (16, 16), 23),      # odd: shapes not block multiples, odd M
    (72, 100, (8, 64), 17),
])
def test_dw_matches_densified_autodiff(n, k, block, m_rows):
    _dw_against_oracle(n, k, block, 0.5, m_rows, backend="ref")


def test_dw_matches_densified_autodiff_pallas_backend():
    _dw_against_oracle(64, 96, (16, 16), 0.5, 32, backend="pallas")


def test_dw_stacked_layers_through_scan():
    """Per-layer dw of a scanned compressed stack equals the dense oracle."""
    rng = np.random.default_rng(7)
    block = (16, 16)
    ws = [_block_sparse(rng, 64, 64, block, d) for d in (0.5, 0.25, 0.75)]
    ms = [dense_to_bcsr(w, block) for w in ws]
    ns = max(m.data.shape[0] for m in ms)
    jm = max(m.gather_idx.shape[1] for m in ms)
    jt = max(m.gather_t_idx.shape[1] for m in ms)
    stk = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[pad_bcsr(m, ns, jm, jt) for m in ms])
    x0 = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)

    def loss(data_stk):
        st = dataclasses.replace(stk, data=data_stk)

        def body(h, wl):
            return jnp.tanh(sparse_ops.sparse_matmul(h, wl)), None

        h, _ = jax.lax.scan(body, x0, st)
        return jnp.sum(h ** 2)

    gd = np.asarray(jax.jit(jax.grad(loss))(stk.data))

    wds = [jnp.asarray(w) for w in ws]

    def dense_loss(wds):
        h = x0
        for wd in wds:
            h = jnp.tanh(h @ wd.T)
        return jnp.sum(h ** 2)

    ogs = jax.grad(dense_loss)(wds)
    for layer, (w, og) in enumerate(zip(ws, ogs)):
        wb = w.reshape(4, 16, 4, 16).transpose(0, 2, 1, 3)
        rows, cols = np.nonzero(np.any(wb != 0, axis=(2, 3)))
        og = np.asarray(og)
        for s, (r, c) in enumerate(zip(rows, cols), start=1):
            np.testing.assert_allclose(
                gd[layer, s], og[r * 16:(r + 1) * 16, c * 16:(c + 1) * 16],
                atol=1e-3, rtol=1e-4)
        # pad_bcsr padding slots carry exactly zero gradient
        np.testing.assert_array_equal(gd[layer, len(rows) + 1:], 0)
        np.testing.assert_array_equal(gd[layer, 0], 0)


def _subjaxprs_of(p):
    if isinstance(p, jax.core.ClosedJaxpr):
        yield p.jaxpr
    elif isinstance(p, jax.core.Jaxpr):
        yield p
    elif isinstance(p, (list, tuple)):
        for q in p:
            yield from _subjaxprs_of(q)


def _all_avals(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            acc.append(tuple(getattr(v.aval, "shape", ())))
        for p in eqn.params.values():
            for sub in _subjaxprs_of(p):
                _all_avals(sub, acc)
    return acc


def test_dw_jaxpr_has_no_dense_out_in_intermediate():
    """Jaxpr-level guarantee: the compressed dw path never materializes a
    dense (out, in) — or padded (out, in) — array. Run on the pallas
    (interpret) backend, where forward, dx and dw all stay in BCSR-land."""
    rng = np.random.default_rng(3)
    n, k, block = 64, 96, (16, 16)
    w = _block_sparse(rng, n, k, block, 0.4)
    mat = dense_to_bcsr(w, block)
    x = jnp.asarray(rng.normal(size=(32, k)), jnp.float32)

    def loss(x, data):
        y = sparse_ops.sparse_matmul(
            x, dataclasses.replace(mat, data=data), backend="pallas")
        return jnp.sum(y ** 2)

    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(x, mat.data)
    shapes = set(_all_avals(jaxpr.jaxpr, []))
    forbidden = {(n, k), (k, n),
                 (mat.block_grid[0] * block[0], mat.block_grid[1] * block[1])}
    assert not (shapes & forbidden), (
        f"dense (out, in) intermediate in the compressed grad path: "
        f"{shapes & forbidden}")


# ---------------------------------------------------------------------------
# Backend dispatch symmetry
# ---------------------------------------------------------------------------

def test_backend_dispatch_symmetry():
    assert sparse_ops.resolve_backend("auto") in ("pallas", "ref")
    with pytest.raises(ValueError):
        sparse_ops.resolve_backend("tpu")
    rng = np.random.default_rng(5)
    w = _block_sparse(rng, 64, 96, (16, 16), 0.5)
    mat = dense_to_bcsr(w, (16, 16))
    x = jnp.asarray(rng.normal(size=(32, 96)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)

    y_p = sparse_ops.sparse_matmul(x, mat, backend="pallas")
    y_r = sparse_ops.sparse_matmul(x, mat, backend="ref")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    t_p = sparse_ops.sparse_matmul_t(dy, mat, backend="pallas")
    t_r = sparse_ops.sparse_matmul_t(dy, mat, backend="ref")
    np.testing.assert_allclose(np.asarray(t_p), np.asarray(t_r),
                               atol=1e-4, rtol=1e-4)

    def loss(x, data, backend):
        y = sparse_ops.sparse_matmul(
            x, dataclasses.replace(mat, data=data), backend=backend)
        return jnp.sum(jnp.tanh(y))

    gx_p, gd_p = jax.grad(loss, argnums=(0, 1))(x, mat.data, "pallas")
    gx_r, gd_r = jax.grad(loss, argnums=(0, 1))(x, mat.data, "ref")
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gd_p), np.asarray(gd_r),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Mask-frozen debias retraining from CompressedParams
# ---------------------------------------------------------------------------

def test_debias_from_compressed_matches_dense_mask_reference():
    """Retrain from a compressed model (only BlockCSR.data + dense residue
    update, masks frozen); debiased compressed logits must match the
    densified mask-frozen reference to 1e-4 and keep the zero pattern."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)

    trainable, rebuild = split_trainable(cp)
    assert trainable["bcsr_data"], "nothing compressed"
    mask = masks_lib.zero_mask(trainable)
    opt = prox_adam(1e-3, lam=0.0)
    st = TrainState(params=trainable, opt_state=opt.init(trainable),
                    mask=mask, step=jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(model, opt, param_transform=rebuild))
    batch = {"inputs": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    losses = []
    for _ in range(5):
        st, metrics = step(st, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], "debias retraining is not learning"

    cp2 = rebuild(st.params)
    moved = any(
        np.any(np.asarray(a) != np.asarray(b))
        for a, b in zip(jax.tree.leaves(trainable["bcsr_data"]),
                        jax.tree.leaves(st.params["bcsr_data"])))
    assert moved, "debias never updated BlockCSR.data"

    dense_ref = densify_compressed(cp2, like=pruned)
    # frozen zero pattern: wherever the pruned reference was zero, the
    # debiased dense reference is still zero
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(dense_ref)):
        za = np.asarray(a) == 0
        assert np.all(np.asarray(b)[za] == 0)

    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                model.cfg.vocab)
    lc, _ = jax.jit(model.prefill)(cp2, prompt, model.init_cache(2, 8))
    ld, _ = jax.jit(model.prefill)(dense_ref, prompt, model.init_cache(2, 8))
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ld),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Zero-slot / empty-BCSR regression
# ---------------------------------------------------------------------------

def test_fully_pruned_model_compresses_serves_and_checkpoints(tmp_path):
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 1.0)       # kill everything
    cp = compress_params(pruned, PLAN)
    # empty BCSRs exist (only the pad slot)
    empties = [m for _, m in iter_bcsr(cp)
               if not np.any(np.asarray(m.data))]
    assert empties, "expected empty BCSRs at sparsity 1.0"

    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                model.cfg.vocab)
    logits, _ = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 8))
    assert np.all(np.isfinite(np.asarray(logits)))

    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, cp, extra={"plan": dataclasses.asdict(PLAN)})
    back = ckpt.restore_compressed(1)
    la, _ = jax.jit(model.prefill)(back, prompt, model.init_cache(2, 8))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(logits))


def test_mixed_empty_and_nonempty_stacked_slices_grads_are_zero():
    """One layer slice fully zero, others not: the stacked BCSR must serve
    the same logits as dense AND give exactly-zero dw for the empty slice
    (pad-slot validity masking in bsr_sddmm)."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.6)
    wi = np.asarray(pruned["layers"]["b0_attn"]["mlp"]["wi"]).copy()
    wi[0] = 0.0                               # layer 0: fully pruned
    pruned["layers"]["b0_attn"]["mlp"]["wi"] = jnp.asarray(wi)
    cp = compress_params(pruned, PLAN)
    m = cp.sparse["layers"]["b0_attn"]["mlp"]["wi"]
    assert not np.any(np.asarray(m.data[0])), "slice 0 should be empty"

    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 4), 0,
                                model.cfg.vocab)
    ld, _ = jax.jit(model.prefill)(pruned, prompt, model.init_cache(2, 8))
    lc, _ = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 8))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc),
                               atol=1e-4, rtol=1e-4)

    trainable, rebuild = split_trainable(cp)

    def loss(tr):
        l, _ = model.prefill(rebuild(tr), prompt, model.init_cache(2, 8))
        return jnp.sum(l ** 2)

    g = jax.jit(jax.grad(loss))(trainable)
    g_wi = np.asarray(g["bcsr_data"]["layers/b0_attn/mlp/wi"])
    assert g_wi.shape == np.asarray(m.data).shape
    np.testing.assert_array_equal(g_wi[0], 0)          # empty slice: no grad
    assert np.any(g_wi[1] != 0), "non-empty slice lost its gradient"


# ---------------------------------------------------------------------------
# End-to-end CLI: train --sparse -> compressed checkpoint -> serve --sparse
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_train_sparse_to_serve_sparse(tmp_path, capsys):
    from repro.launch import serve as serve_launch
    from repro.launch import train as train_launch

    cp, hist_spc, hist_db, report = train_launch.main(
        ["--arch", "smollm-360m", "--reduced", "--sparse",
         "--steps", "12", "--debias-steps", "3", "--batch", "2",
         "--seq", "16", "--lr", "3e-3", "--compress", "group_l1:100",
         "--block", "8", "64", "--ckpt-dir", str(tmp_path),
         "--log-every", "4"])
    assert isinstance(cp, CompressedParams)
    assert cp.sparse, "SpC training compressed nothing"
    assert report["bcsr_bytes"] < report["dense_bytes"]

    out = serve_launch.main(
        ["--arch", "smollm-360m", "--reduced", "--sparse",
         "--ckpt-dir", str(tmp_path), "--batch", "2",
         "--prompt-len", "4", "--gen", "4"])
    assert out.shape == (2, 4)
    printed = capsys.readouterr().out
    assert "bcsr=" in printed and "compressed checkpoint" in printed
