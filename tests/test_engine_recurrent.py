"""Recurrent architectures under the continuous-batching engine: the slot
resource pool refactor's acceptance gates.

The load-bearing guarantees:
  * per-token parity (greedy, tolerance 0) between the engine and the
    sequential ``generate`` path for rwkv6-3b (pure RWKV) and
    recurrentgemma-9b (2:1 RG-LRU:attention hybrid with remainder layers
    and a sliding window) under a mixed batch with chunked prefill —
    dense and BlockCSR-compressed weights,
  * int8-KV attention configs serve through int8 page pools: the paged
    mixed step matches ``Model.prefill`` at int8 tolerance and the engine
    stays self-consistent token-for-token,
  * the compiled tick-width invariant carries over: request churn on
    recurrent/hybrid models never adds a step shape,
  * recycled slots leak no recurrent state: pools are zeroed between
    occupants and a second wave on a reused engine still matches generate,
  * ``slot_resource_bytes`` splits the pool tree correctly by kind.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model_zoo import build, get_config
from repro.models.transformer import make_model
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.paged_kv import (init_paged_cache, paged_cache_bytes,
                                  pages_for, slot_resource_bytes)
from repro.serve.step import generate
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   prune_blocks_for_plan)

GEN = 5
PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


@pytest.fixture(scope="module", params=["rwkv6-3b", "recurrentgemma-9b"])
def arch_setup(request):
    model = build(request.param, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, model, params


def _prompts(lens, vocab, seed=7):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (L,), 0, vocab), np.int32)
            for i, L in enumerate(lens)]


def _assert_parity(model, params, lens, *, max_batch, prefill_chunk=8,
                   gen=GEN, **cfg_kw):
    prompts = _prompts(lens, model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=max_batch,
                                   prefill_chunk=prefill_chunk, page_size=4,
                                   max_seq_len=max(lens) + gen, **cfg_kw))
    out = eng.run([(p, gen) for p in prompts])
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], gen))[0]
        np.testing.assert_array_equal(
            out["results"][rid], ref,
            err_msg=f"request {rid} (prompt_len={len(p)})")
    return eng, out


def test_recurrent_engine_token_parity_mixed_batch(arch_setup):
    """4 concurrent mixed-length requests with chunked prefill (prompts on
    both sides of the chunk width), tokens == generate() exactly."""
    arch, model, params = arch_setup
    eng, _ = _assert_parity(model, params, [5, 12, 3, 20], max_batch=4)
    assert eng.scheduler.n_prefill_chunks > 4      # 12/20 really chunked
    assert eng.tick_widths == {1, 8}               # no extra compiled shape


def test_recurrent_engine_compressed_parity(arch_setup):
    """Same gate from BlockCSR-compressed weights: the recurrent
    projections dispatch sparse_matmul inside the engine's mixed step."""
    arch, model, params = arch_setup
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)
    _assert_parity(model, cp, [5, 12, 3], max_batch=3)


def test_recurrent_engine_churn_keeps_tick_widths(arch_setup):
    """More requests than slots: admissions, finishes and slot recycling
    across waves never add a compiled tick width (the no-recompile
    invariant the attention path has)."""
    arch, model, params = arch_setup
    eng, out = _assert_parity(model, params, [5, 12, 3, 9, 6, 14],
                              max_batch=2)
    assert out["stats"]["n_requests"] == 6
    assert eng.tick_widths == {1, 8}


def test_recurrent_state_zeroed_on_recycle(arch_setup):
    """Slot hygiene: after a drain every state-pool leaf is zero (no
    leakage to a slot's next occupant), and a second wave on the same
    engine still matches generate."""
    arch, model, params = arch_setup
    eng, _ = _assert_parity(model, params, [7, 11, 4], max_batch=2)

    def state_leaves(pools):
        out = []
        for group in ("layers", "rem"):
            for layer in (pools.get(group) or {}).values():
                for key, sub in layer.items():
                    if key != "attn":
                        out.extend(jax.tree.leaves(sub))
        return out

    leaves = state_leaves(eng.pools)
    assert leaves                                  # recurrent arch: nonempty
    for leaf in leaves:
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)

    # second wave through the SAME engine (recycled slots all around)
    prompts = _prompts([9, 5], model.cfg.vocab, seed=11)
    out2 = eng.run([(p, GEN) for p in prompts])
    rid0 = min(out2["results"])
    for i, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(out2["results"][rid0 + i], ref)


def test_slot_resource_bytes_split(arch_setup):
    """Pure-RWKV pools are all state (kv_page_bytes == 0); the RG-LRU:attn
    hybrid carries both kinds; the split sums to the total."""
    arch, model, params = arch_setup
    pools = init_paged_cache(model, 9, 4, capacity=4)
    split = slot_resource_bytes(pools)
    assert split["state_slot_bytes"] > 0
    if arch == "rwkv6-3b":
        assert split["kv_page_bytes"] == 0
    else:
        assert split["kv_page_bytes"] > 0
    assert (split["kv_page_bytes"] + split["state_slot_bytes"]
            == paged_cache_bytes(pools))


def test_attention_pools_all_kv_bytes():
    model = build("smollm-360m", reduced=True)
    pools = init_paged_cache(model, 9, 4, capacity=4)
    split = slot_resource_bytes(pools)
    assert split["state_slot_bytes"] == 0
    assert split["kv_page_bytes"] == paged_cache_bytes(pools) > 0


# ---------------------------------------------------------------------------
# Int8 paged KV pools
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def int8_model():
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              kv_cache_dtype="int8")
    model = make_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_int8_paged_step_matches_prefill(int8_model):
    """Int8 page pools: the paged mixed step over a whole prompt matches
    Model.prefill at int8 tolerance (both attend over quantized K/V in
    decode; prefill's attention runs unquantized, so the bound is the
    quantization noise, not fp rounding)."""
    model, params = int8_model
    L, ps = 12, 4
    prompt = _prompts([L], model.cfg.vocab)[0]
    n_pages = pages_for(L, ps)
    pools = init_paged_cache(model, n_pages + 1, ps)
    assert pools["layers"]["b0_attn"]["attn"]["k"].dtype == jnp.int8
    assert "k_scale" in pools["layers"]["b0_attn"]["attn"]
    table = np.zeros((1, n_pages), np.int32)
    table[0] = np.arange(1, n_pages + 1)
    logits, _ = model.paged_step(
        params, jnp.asarray(prompt)[None, :], pools, jnp.asarray(table),
        jnp.zeros((1,), jnp.int32), jnp.full((1,), L, jnp.int32))
    cache = model.init_cache(1, L + 1)
    ref, _ = model.prefill(params, jnp.asarray(prompt)[None, :], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=5e-2, rtol=0)
    assert int(np.asarray(logits).argmax()) == int(np.asarray(ref).argmax())


def test_int8_engine_serves_and_halves_pool_bytes(int8_model):
    """The engine serves an int8-KV config end to end (mixed batch,
    chunked prefill); every request's tokens agree with generate at the
    greedy level for most steps — asserted per-token against a fp-pool
    engine's trajectory is NOT required at int8, so the gate is: the run
    completes, the first token after prefill matches generate's, and the
    int8 pools store ~half the bytes of the fp32 pools."""
    model, params = int8_model
    prompts = _prompts([5, 12, 3], model.cfg.vocab)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=3, prefill_chunk=8, page_size=4,
                                   max_seq_len=24))
    out = eng.run([(p, GEN) for p in prompts])
    assert out["stats"]["n_requests"] == 3
    assert eng.tick_widths == {1, 8}
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        assert out["results"][rid][0] == ref[0], f"request {rid} first token"

    # byte accounting: int8 pools (k/v int8 + f32 scales) vs fp32 pools
    fp_model = make_model(dataclasses.replace(model.cfg,
                                              kv_cache_dtype="compute"))
    int8_bytes = paged_cache_bytes(init_paged_cache(model, 9, 4))
    fp_bytes = paged_cache_bytes(init_paged_cache(fp_model, 9, 4))
    hd = model.cfg.resolved_head_dim
    assert int8_bytes == pytest.approx(fp_bytes * (1 + 4 / hd) / 4, rel=1e-6)


# ---------------------------------------------------------------------------
# Priority preemption on recurrent-state slots
# ---------------------------------------------------------------------------

def test_recurrent_preempt_resume_parity(arch_setup):
    """A batch-class recurrent request preempted mid-decode by an
    interactive arrival (capacity 1) loses its state slot entirely —
    recurrent state is not pageable, so the freed slot is zeroed and the
    resume re-prefills prompt + generated from scratch. Both requests
    must still match uninterrupted generate() token for token."""
    arch, model, params = arch_setup
    prompts = _prompts([9, 6], model.cfg.vocab, seed=13)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=1, prefill_chunk=8, page_size=4,
                                   max_seq_len=24))
    finished = []
    eng.submit(prompts[0], 7, priority="batch")
    for _ in range(4):                       # prefill + a few decode ticks
        finished.extend(eng.step())
    eng.submit(prompts[1], 3, priority="interactive")
    while eng.scheduler.has_work():
        finished.extend(eng.step())
    recs = {r["rid"]: r for r in finished}
    assert eng.scheduler.n_preemptions >= 1
    assert recs[0]["n_preempted"] >= 1
    assert [r["rid"] for r in finished].index(1) < \
        [r["rid"] for r in finished].index(0)
    for rid, gen in ((0, 7), (1, 3)):
        ref = np.asarray(generate(model, params,
                                  prompts[rid][None, :], gen))[0]
        np.testing.assert_array_equal(recs[rid]["tokens"], ref,
                                      err_msg=f"{arch} request {rid}")


def test_recurrent_rejects_prefix_cache(arch_setup):
    """Prefix caching shares position-sliceable KV pages; recurrent state
    is a single running summary, so the engine must refuse the combination
    with a clear error instead of serving wrong tokens."""
    arch, model, params = arch_setup
    with pytest.raises(NotImplementedError, match="prefix-cache"):
        ServeEngine(model, params, EngineConfig(prefix_cache=True))
