"""serve/step.py sampling (greedy / temperature / top-k / top-p) and
serve/kvcache.py helpers (cache_spec no-allocation property, cache_bytes
arithmetic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model_zoo import build
from repro.serve.kvcache import cache_bytes, cache_spec
from repro.serve.step import make_sampler, sample_token

# one peaked + tail distribution: probs 0.5, 0.3, 0.15, 0.05
LOGITS = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))


def _draws(n=300, **kw):
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    toks = jax.vmap(lambda k: sample_token(LOGITS, 1.0, k, **kw))(keys)
    return np.asarray(toks).ravel()


def test_greedy_is_argmax():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [9.0, 0.0, 1.0]])
    np.testing.assert_array_equal(sample_token(logits), [1, 0])
    # no rng means greedy even with temperature set
    np.testing.assert_array_equal(sample_token(logits, 0.7), [1, 0])
    assert sample_token(logits).dtype == jnp.int32


def test_temperature_sampling_covers_support():
    toks = _draws()
    assert set(np.unique(toks)) == {0, 1, 2, 3}       # full support at T=1
    # near-zero temperature concentrates on the argmax
    keys = jax.random.split(jax.random.PRNGKey(1), 50)
    cold = jax.vmap(lambda k: sample_token(LOGITS, 0.05, k))(keys)
    assert set(np.unique(np.asarray(cold))) == {0}


def test_top_k_restricts_support():
    toks = _draws(top_k=2)
    assert set(np.unique(toks)) <= {0, 1}
    assert len(set(np.unique(toks))) == 2             # both survivors drawn
    # top_k=1 is greedy regardless of rng
    assert set(np.unique(_draws(n=50, top_k=1))) == {0}


def test_top_p_restricts_support():
    # top_p=0.7: exclusive cumprobs are 0 / 0.5 / 0.8 -> keep {0, 1}
    toks = _draws(top_p=0.7)
    assert set(np.unique(toks)) <= {0, 1}
    assert len(set(np.unique(toks))) == 2
    # a tiny top_p always keeps the argmax (never an empty support)
    assert set(np.unique(_draws(n=50, top_p=1e-6))) == {0}
    # top_p=1.0 is a no-op: full support
    assert set(np.unique(_draws(top_p=1.0))) == {0, 1, 2, 3}


def test_top_k_and_top_p_compose():
    # top_k=3 keeps {0,1,2}; then top_p=0.7 over the survivors
    # (renormalized probs ~0.526/0.316/0.158 -> exclusive cum 0/.526/.842)
    toks = _draws(top_k=3, top_p=0.7)
    assert set(np.unique(toks)) <= {0, 1}


def test_make_sampler_is_jit_stable():
    sampler = make_sampler(temperature=1.0, top_k=2)
    jitted = jax.jit(sampler)
    tok = jitted(LOGITS, jax.random.PRNGKey(3))
    assert int(tok[0]) in (0, 1)
    greedy = jax.jit(make_sampler())                  # no-rng greedy path
    np.testing.assert_array_equal(greedy(LOGITS), [0])


# ---------------------------------------------------------------------------
# serve/kvcache.py helpers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    return build("smollm-360m", reduced=True)


def test_cache_spec_allocates_nothing_and_matches_init_cache(model):
    spec = cache_spec(model, batch=2, seq_len=16)
    leaves = jax.tree.leaves(spec)
    assert leaves and all(isinstance(l, jax.ShapeDtypeStruct)
                          for l in leaves)            # no arrays materialized
    real = model.init_cache(2, 16)
    real_shapes = jax.tree.map(lambda x: (x.shape, x.dtype), real)
    spec_shapes = jax.tree.map(lambda x: (x.shape, x.dtype), spec)
    assert real_shapes == spec_shapes


def test_cache_bytes_arithmetic(model):
    spec = cache_spec(model, batch=2, seq_len=16)
    expect = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree.leaves(spec))
    assert cache_bytes(spec) == expect
    # bytes scale linearly in batch and seq for the attention ring cache
    assert cache_bytes(cache_spec(model, 4, 16)) == 2 * expect
    assert cache_bytes(cache_spec(model, 2, 32)) == 2 * expect
