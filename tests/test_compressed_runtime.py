"""End-to-end tests for the compressed-model runtime.

Covers the acceptance criteria of the unified-compressed-runtime refactor:
  * compressed decode logits match dense decode on a reduced config,
  * ``launch.serve --sparse`` actually dispatches ``sparse_matmul`` on the
    prefill + decode paths and reports real BCSR bytes,
  * a compressed checkpoint round-trips through ``Checkpointer`` bit-exactly
    (no densification),
  * one-shot prefill equals stepwise decode over the prompt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.models.model_zoo import build
from repro.serve.step import generate, make_decode_step
from repro.sparse import ops as sparse_ops
from repro.sparse.compress import (CompressedParams, CompressionPlan,
                                   compress_params, compressed_size_bytes,
                                   prune_blocks_for_plan)
from repro.sparse.formats import BlockCSR, bcsr_to_dense

PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


@pytest.fixture(scope="module")
def reduced_setup():
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    cp = compress_params(pruned, PLAN)
    return model, pruned, cp


def test_compress_produces_bcsr_entries(reduced_setup):
    _, _, cp = reduced_setup
    assert isinstance(cp, CompressedParams)
    layers = cp.sparse["layers"]
    names = {n for lk in layers for sub in layers[lk]
             for n in layers[lk][sub]}
    assert {"wq", "wk", "wv", "wo", "wi"} <= names
    # stacked over n_super: data has a leading layer axis
    m = next(iter(layers.values()))["mlp"]["wi"]
    assert isinstance(m, BlockCSR) and m.data.ndim == 4


def test_compressed_entries_match_pruned_dense(reduced_setup):
    _, pruned, cp = reduced_setup
    wi = np.asarray(pruned["layers"]["b0_attn"]["mlp"]["wi"])  # (L, d, ff)
    m = cp.sparse["layers"]["b0_attn"]["mlp"]["wi"]
    for layer in range(wi.shape[0]):
        sl = jax.tree.map(lambda a: a[layer], m)
        dense = np.asarray(bcsr_to_dense(sl))[:m.shape[0], :m.shape[1]]
        np.testing.assert_array_equal(dense, wi[layer].T)


def test_compressed_decode_matches_dense(reduced_setup):
    model, pruned, cp = reduced_setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                model.cfg.vocab)
    cache_d = model.init_cache(2, 16)
    cache_c = model.init_cache(2, 16)
    ld, cache_d = jax.jit(model.prefill)(pruned, prompt, cache_d)
    lc, cache_c = jax.jit(model.prefill)(cp, prompt, cache_c)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc),
                               atol=1e-4, rtol=1e-4)
    tok = jnp.argmax(ld, -1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    ld2, _ = step(pruned, tok, cache_d, jnp.int32(8))
    lc2, _ = step(cp, tok, cache_c, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(ld2), np.asarray(lc2),
                               atol=1e-4, rtol=1e-4)


def test_serve_sparse_dispatches_sparse_matmul(monkeypatch, capsys):
    """`--sparse` serving must hit the compressed kernel on the decode path
    and report BCSR bytes — the tentpole acceptance check."""
    from repro.launch import serve as serve_launch

    calls = {"n": 0}
    real = sparse_ops.sparse_matmul

    def counting(x, w, backend="auto"):
        calls["n"] += 1
        return real(x, w, backend)

    monkeypatch.setattr(sparse_ops, "sparse_matmul", counting)
    out = serve_launch.main(["--arch", "smollm-360m", "--reduced", "--sparse",
                             "--batch", "2", "--prompt-len", "4",
                             "--gen", "4", "--block", "8", "64",
                             "--sparsity", "0.75"])
    assert out.shape == (2, 4)
    assert calls["n"] > 0, "no sparse_matmul dispatch on the serving path"
    printed = capsys.readouterr().out
    assert "bcsr=" in printed and "dense=" in printed


def test_compressed_size_is_smaller(reduced_setup):
    _, pruned, cp = reduced_setup
    dense_b = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(pruned))
    assert compressed_size_bytes(cp) < dense_b


def test_compressed_checkpoint_roundtrip(tmp_path, reduced_setup):
    _, _, cp = reduced_setup
    ckpt = Checkpointer(str(tmp_path), keep_n=2)
    ckpt.save(7, cp)
    back = ckpt.restore(7, like=cp)

    flat_a, tda = jax.tree_util.tree_flatten(cp)
    flat_b, tdb = jax.tree_util.tree_flatten(back)
    assert tda == tdb                       # BlockCSR metas included
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # manifest records the compressed leaves as bcsr, not densified
    fmts = {e["format"] for e in ckpt.manifest(7)["leaves"]}
    assert "bcsr" in fmts


def test_prefill_matches_stepwise_decode():
    """One-shot prefill must leave logits + cache equivalent to feeding the
    prompt token-by-token through decode_step."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(3))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 0,
                                model.cfg.vocab)
    b, s = prompt.shape

    cache_p = model.init_cache(b, s + 4)
    logits_p, cache_p = jax.jit(model.prefill)(params, prompt, cache_p)

    cache_s = model.init_cache(b, s + 4)
    step = jax.jit(model.decode_step)
    for t in range(s):
        logits_s, cache_s = step(params, prompt[:, t:t + 1], cache_s,
                                 jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_s[:, 0]),
                               atol=2e-3, rtol=2e-3)
    # continuing decode from either cache agrees
    tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    lp, _ = step(params, tok, cache_p, jnp.int32(s))
    ls, _ = step(params, tok, cache_s, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ls),
                               atol=2e-3, rtol=2e-3)


def test_generate_with_compressed_params(reduced_setup):
    model, pruned, cp = reduced_setup
    prompt = jax.random.randint(jax.random.PRNGKey(5), (2, 4), 0,
                                model.cfg.vocab)
    out_d = generate(model, pruned, prompt, 5)
    out_c = generate(model, cp, prompt, 5)
    assert out_c.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_c))
