"""Data pipeline determinism + sharding rules + gradient compression +
HLO cost parser units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, ShardedBatcher
from repro.data.synthetic import (TokenStreamConfig, image_batch, token_batch,
                                  MNIST_LIKE)
from repro.distributed import collectives
from repro.distributed.elastic import rescale_plan
from repro.distributed.sharding import (_axes_to_spec, ACT_RULES,
                                        param_logical_axes, PARAM_RULES)
from repro.launch.mesh import make_host_mesh


def test_token_batch_deterministic():
    cfg = TokenStreamConfig(vocab=100, seq_len=16, global_batch=4)
    a = token_batch(cfg, 7)
    b = token_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))
    c = token_batch(cfg, 8)
    assert not np.array_equal(np.asarray(a["inputs"]),
                              np.asarray(c["inputs"]))


def test_token_batch_labels_shifted():
    cfg = TokenStreamConfig(vocab=100, seq_len=16, global_batch=2)
    b = token_batch(cfg, 0)
    np.testing.assert_array_equal(np.asarray(b["inputs"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_sharded_batcher_host_slices_tile_global():
    cfg = TokenStreamConfig(vocab=100, seq_len=8, global_batch=8)
    full = token_batch(cfg, 3)
    parts = [ShardedBatcher(cfg, process_index=i, process_count=4).batch(3)
             for i in range(4)]
    stacked = np.concatenate([np.asarray(p["inputs"]) for p in parts])
    np.testing.assert_array_equal(stacked, np.asarray(full["inputs"]))


def test_prefetcher_orders_steps():
    cfg = TokenStreamConfig(vocab=50, seq_len=4, global_batch=2)
    pf = Prefetcher(lambda s: token_batch(cfg, s), start_step=5, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_image_batch_class_conditional():
    b = image_batch(MNIST_LIKE, 0)
    assert b["inputs"].shape == (128, 28, 28, 1)
    assert int(jnp.max(b["labels"])) <= 9


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_axes_to_spec_divisibility_fallback():
    mesh = make_host_mesh(1, 1)

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = _axes_to_spec(("batch", "heads"), (32, 15), FakeMesh, ACT_RULES)
    # batch 32 divisible by data(16) [pod absent]; heads 15 NOT divisible -> None
    assert spec[1] is None
    spec2 = _axes_to_spec(("batch", "heads"), (32, 32), FakeMesh, ACT_RULES)
    assert spec2[1] == "model"


def test_axes_no_duplicate_mesh_axis():
    class FakeMesh:
        shape = {"data": 4, "model": 4}
    # both logical axes map to 'model'; second must not reuse it
    spec = _axes_to_spec(("heads", "mlp"), (8, 8), FakeMesh, ACT_RULES)
    assert spec[0] == "model" and spec[1] is None


def test_param_logical_axes_patterns():
    params = {"layers": {"b0_attn": {"attn": {
        "wq": jnp.zeros((2, 8, 4, 16)),     # stacked (layers, d, h, hd)
        "wo": jnp.zeros((2, 4, 16, 8)),
    }, "mlp": {"wi": jnp.zeros((2, 8, 32))}}},
        "embed": {"embedding": jnp.zeros((100, 8))}}
    axes = param_logical_axes(params)
    assert axes["layers"]["b0_attn"]["attn"]["wq"] == \
        ("layers", "embed", "heads", "head_dim")
    assert axes["embed"]["embedding"] == ("vocab", "embed")
    assert axes["layers"]["b0_attn"]["mlp"]["wi"] == ("layers", "embed", "mlp")


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = collectives.quantize_int8(x)
    err = np.abs(np.asarray(collectives.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_compensates():
    """With error feedback, the accumulated applied signal tracks the true
    accumulated gradient far better than independent quantization."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.normal(size=(64,)) * 1e-3, jnp.float32)
             for _ in range(50)]
    err = None
    applied_ef = jnp.zeros(64)
    applied_nq = jnp.zeros(64)
    for g in grads:
        (dq,), err = collectives.ef_compress_grads((g,), err)
        applied_ef += dq
        q, s = collectives.quantize_int8(g)
        applied_nq += collectives.dequantize_int8(q, s)
    true = sum(np.asarray(g) for g in grads)
    ef_err = np.linalg.norm(np.asarray(applied_ef) - true)
    assert ef_err <= np.linalg.norm(true) * 0.05


def test_rescale_plan():
    plan = rescale_plan({"data": 16, "model": 16},
                        {"pod": 2, "data": 16, "model": 16}, 256)
    assert plan["new_dp"] == 32 and plan["batch_divisible"]
    assert plan["per_replica_batch"] == 8


# ---------------------------------------------------------------------------
# HLO cost parser units
# ---------------------------------------------------------------------------

def test_hlo_parser_counts_dot_and_while():
    from repro.roofline.hlo_cost import module_cost

    def f(w, x):
        def body(x, wi):
            return jnp.dot(x, wi, preferred_element_type=jnp.float32), None
        x, _ = jax.lax.scan(body, x, w)
        return x

    w = jnp.zeros((6, 32, 32))
    x = jnp.zeros((8, 32))
    comp = jax.jit(f).lower(w, x).compile()
    c = module_cost(comp.as_text())
    assert c.flops == pytest.approx(6 * 2 * 8 * 32 * 32, rel=0.01)


def test_hlo_parser_conv():
    from repro.roofline.hlo_cost import module_cost

    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    x = jnp.zeros((2, 8, 8, 3))
    k = jnp.zeros((3, 3, 3, 16))
    comp = jax.jit(f).lower(x, k).compile()
    c = module_cost(comp.as_text())
    want = 2 * (2 * 8 * 8 * 16) * (3 * 3 * 3)
    assert c.flops == pytest.approx(want, rel=0.05)
