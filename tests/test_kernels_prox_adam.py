"""Fused Prox-ADAM Pallas kernel vs ref.py oracle and core optimizer.

The hypothesis sweep runs when the package is installed; a seeded
parametrized fallback covers the same invariant otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers
from repro.kernels.prox_adam import ops as pops

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.mark.parametrize("shape", [(256, 128), (333, 77), (5,), (1000,),
                                   (3, 5, 7)])
@pytest.mark.parametrize("rule", ["adam", "rmsprop"])
def test_fused_vs_ref(shape, rule):
    rng = np.random.default_rng(hash((shape, rule)) % 2**31)
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    g = jnp.asarray(rng.normal(size=shape), jnp.float32)
    m = jnp.asarray(rng.normal(size=shape) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=shape)) * 0.01, jnp.float32)
    sc = pops.make_scalars(1e-2, 3.0, 0.9, 0.999, 1e-8, t=7)

    got = pops.fused_update_leaf(w, g, m, v, sc, rule=rule)
    want = pops.fused_prox_update_ref(w, g, m, v, sc, rule=rule)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def _sweep_case(n, lr, lam, t):
    rng = np.random.default_rng(n)
    w = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    sc = pops.make_scalars(lr, lam, 0.9, 0.999, 1e-8, t=t)
    w2, m2, v2 = pops.fused_update_leaf(w, g, z, z, sc, rule="adam")
    wr, mr, vr = pops.fused_prox_update_ref(w, g, z, z, sc, rule="adam")
    np.testing.assert_allclose(np.asarray(w2), np.asarray(wr), atol=1e-5)


@pytest.mark.parametrize("seed", range(6))
def test_fused_sweep_seeded(seed):
    rng = np.random.default_rng(seed)
    _sweep_case(int(rng.integers(1, 4097)), float(rng.uniform(1e-4, 1.0)),
                float(rng.uniform(0.0, 10.0)), int(rng.integers(1, 101)))


if HAVE_HYPOTHESIS:
    @hypothesis.given(st.integers(1, 4096), st.floats(1e-4, 1.0),
                      st.floats(0.0, 10.0), st.integers(1, 100))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_fused_property_sweep(n, lr, lam, t):
        _sweep_case(n, lr, lam, t)


def test_fused_matches_core_optimizer_trajectory():
    """Multi-step: fused kernel trajectory == pure optimizer trajectory."""
    rng = np.random.default_rng(0)
    shape = (64, 48)
    params = {"w": jnp.asarray(rng.normal(size=shape), jnp.float32)}
    opt = optimizers.prox_adam(5e-2, lam=1.0)
    st = opt.init(params)

    wk = params["w"]
    mk = jnp.zeros(shape, jnp.float32)
    vk = jnp.zeros(shape, jnp.float32)
    for t in range(1, 6):
        g = jnp.asarray(rng.normal(size=shape), jnp.float32)
        params, st = opt.update({"w": g}, st, params)
        sc = pops.make_scalars(5e-2, 1.0, 0.9, 0.999, 1e-8, t=t)
        wk, mk, vk = pops.fused_update_leaf(wk, g, mk, vk, sc, rule="adam")
        np.testing.assert_allclose(np.asarray(wk), np.asarray(params["w"]),
                                   atol=1e-5)
    assert float(jnp.mean(wk == 0)) > 0.05   # prox produced zeros


def test_fused_tree_update_respects_predicate():
    tree = {"kernel": jnp.full((128, 128), 1e-4),
            "bias": jnp.full((128,), 1e-4)}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    sc = pops.make_scalars(1e-3, 10.0, 0.9, 0.999, 1e-8, t=1)
    p2, _, _ = pops.fused_tree_update(tree, zeros, zeros, zeros, sc)
    assert np.all(np.asarray(p2["kernel"]) == 0)     # prox'd to zero
    assert np.all(np.asarray(p2["bias"]) != 0)       # bias skips prox
