"""Pallas BCSR spmm kernel vs pure-jnp oracle: shape/dtype/density sweeps
(interpret mode on CPU; the kernel targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsr_spmm import ops
from repro.kernels.bsr_spmm.ref import gather_block_matmul_ref
from repro.kernels.bsr_spmm.bsr_spmm import gather_block_matmul
from repro.sparse.formats import dense_to_bcsr


def _block_sparse(rng, n, k, block, density):
    br, bc = block
    w = np.zeros((n, k), np.float32)
    for i in range(n // br):
        for j in range(k // bc):
            if rng.random() < density:
                w[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = rng.normal(
                    size=(br, bc))
    return w


@pytest.mark.parametrize("n,k,block", [
    (64, 64, (32, 32)), (96, 160, (32, 32)), (64, 128, (8, 128)),
    (128, 64, (16, 16)),
])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_spmm_fwd_shapes(n, k, block, density):
    rng = np.random.default_rng(hash((n, k, density)) % 2**31)
    w = _block_sparse(rng, n, k, block, density)
    m = dense_to_bcsr(w, block)
    x = jnp.asarray(rng.normal(size=(40, k)), jnp.float32)
    y = ops.spmm(x, m, bm=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w.T,
                               atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("n,k,block", [(96, 160, (32, 32)), (64, 64, (8, 128))])
def test_spmm_bwd_shapes(n, k, block):
    rng = np.random.default_rng(0)
    w = _block_sparse(rng, n, k, block, 0.4)
    m = dense_to_bcsr(w, block)
    dy = jnp.asarray(rng.normal(size=(24, n)), jnp.float32)
    dx = ops.spmm_t(dy, m, bm=8)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dy) @ w,
                               atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    rng = np.random.default_rng(3)
    w = _block_sparse(rng, 64, 64, (32, 32), 0.5).astype(dtype)
    m = dense_to_bcsr(np.asarray(w, np.float32), (32, 32))
    m = jax.tree.map(lambda a: a.astype(dtype)
                     if a.dtype == jnp.float32 else a, m)
    x = jnp.asarray(rng.normal(size=(32, 64)), dtype)
    y = ops.spmm(x, m, bm=32)
    ref = np.asarray(x, np.float32) @ np.asarray(w, np.float32).T
    tol = 1e-4 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                               atol=tol, rtol=tol)


def test_kernel_vs_schedule_oracle():
    """The pallas grid schedule itself vs an index-faithful python oracle."""
    rng = np.random.default_rng(4)
    w = _block_sparse(rng, 64, 96, (32, 32), 0.5)
    m = dense_to_bcsr(w, (32, 32))
    x = jnp.asarray(rng.normal(size=(32, 96)), jnp.float32)
    got = gather_block_matmul(x, m.data, m.gather_idx, m.gather_blk,
                              m.gather_nnz, out_cols=64,
                              transpose_block=True, bm=32, interpret=True)
    want = gather_block_matmul_ref(x, m.data, m.gather_idx, m.gather_blk,
                                   m.gather_nnz, out_cols=64,
                                   transpose_block=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_custom_vjp_matches_dense_grad():
    rng = np.random.default_rng(5)
    w = _block_sparse(rng, 64, 64, (32, 32), 0.6)
    m = dense_to_bcsr(w, (32, 32))
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)

    g_sparse = jax.grad(lambda x_: jnp.sum(jnp.tanh(ops.spmm_ad(x_, m))))(x)
    g_dense = jax.grad(
        lambda x_: jnp.sum(jnp.tanh(x_ @ jnp.asarray(w).T)))(x)
    np.testing.assert_allclose(np.asarray(g_sparse), np.asarray(g_dense),
                               atol=1e-4, rtol=1e-4)


def test_ragged_rows_padded_gather():
    """Rows with very different nnz exercise the padded gather tables."""
    w = np.zeros((96, 96), np.float32)
    w[:32, :] = np.random.default_rng(6).normal(size=(32, 96))  # dense row 0
    w[32:64, :32] = 1.0                                          # 1 block
    # block-row 2 empty
    m = dense_to_bcsr(w, (32, 32))
    assert int(m.gather_nnz[0]) == 3
    assert int(m.gather_nnz[1]) == 1
    assert int(m.gather_nnz[2]) == 0
    x = jnp.asarray(np.eye(96, dtype=np.float32))
    y = ops.spmm(x, m, bm=8)
    np.testing.assert_allclose(np.asarray(y), w.T, atol=1e-5)
