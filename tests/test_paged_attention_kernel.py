"""Paged-attention kernel suite (kernels/paged_attention): parity of the
fused page-gather flash-decode Pallas kernel (interpret mode) against the
jnp gather-the-whole-pool reference, across

  * GQA group sizes (g = 1 and g = 4),
  * sliding-window configs (None and a window smaller than the context),
  * odd ``n_tokens`` mixes — decode slots (1 token), prefill chunks and
    inactive slots (0 tokens, page table all trash) in one tick,
  * trash-page rows (invalid tokens write/read page 0 harmlessly),
  * the flash-decode KV-split combine identity (1 split == N splits),

plus the jaxpr-level guarantee that the pallas backend of
``models/attention.paged_attention`` never materializes the gathered
``(B, P*page_size, kv, hd)`` context, and model-level backend symmetry of
``Model.paged_step``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention import ref as paged_ref
from repro.models import attention
from repro.models.model_zoo import build
from repro.serve.paged_kv import init_paged_cache


def _scenario(rng, *, b=3, c=8, kv=2, g=3, hd=16, ps=4, p_log=6,
              starts=(0, 5, 13), n_tok=(8, 1, 5)):
    """Mixed tick: slot 0 a full chunk, slot 1 a decode, slot 2 a partial
    chunk (or whatever ``n_tok`` says). Pools hold garbage everywhere —
    including the trash page — so masking bugs show up as real diffs."""
    h = kv * g
    n_pages = 1 + b * p_log
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, kv, hd)), jnp.float32)
    table = jnp.asarray(
        1 + np.arange(b * p_log, dtype=np.int32).reshape(b, p_log))
    starts = np.asarray(starts, np.int32)
    positions = jnp.asarray(starts[:, None] + np.arange(c)[None], jnp.int32)
    valid = np.arange(c)[None, :] < np.asarray(n_tok)[:, None]
    return q, kp, vp, table, positions, valid


@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("window", [None, 8])
def test_kernel_matches_ref_gqa_and_window(g, window):
    rng = np.random.default_rng(0)
    q, kp, vp, table, positions, valid = _scenario(rng, g=g)
    out = paged_ops.paged_flash_attention(q, kp, vp, table, positions,
                                          window=window, interpret=True)
    ref = paged_ref.paged_attention_ref(q, kp, vp, table, positions,
                                        window=window)
    err = np.abs(np.asarray(out) - np.asarray(ref))[valid].max()
    assert err < 1e-4, (g, window, err)
    assert np.isfinite(np.asarray(out)).all()   # invalid rows: finite junk


def test_kernel_mixed_ticks_with_inactive_and_trash_slots():
    """Odd n_tokens mix incl. an inactive slot whose page-table row is all
    zeros (every lookup hits the trash page): valid rows still match the
    reference exactly, and nothing goes non-finite."""
    rng = np.random.default_rng(1)
    q, kp, vp, table, positions, valid = _scenario(
        rng, b=4, c=6, starts=(0, 9, 2, 0), n_tok=(6, 1, 3, 0))
    table = table.at[3].set(0)                   # inactive slot: all trash
    out = paged_ops.paged_flash_attention(q, kp, vp, table, positions,
                                          interpret=True)
    ref = paged_ref.paged_attention_ref(q, kp, vp, table, positions)
    err = np.abs(np.asarray(out) - np.asarray(ref))[valid].max()
    assert err < 1e-4, err
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("window", [None, 6])
def test_kv_split_combine_identity(window):
    """Flash-decode cross-split combine: N split lanes reduce to the same
    output as the unsplit walk (to float rounding), including lanes whose
    pages are all causally skipped (empty partials drop out)."""
    rng = np.random.default_rng(2)
    q, kp, vp, table, positions, valid = _scenario(rng, starts=(0, 3, 20),
                                                   p_log=8, n_tok=(8, 1, 4))
    one = paged_ops.paged_flash_attention(q, kp, vp, table, positions,
                                          window=window, kv_splits=1,
                                          interpret=True)
    for splits in (2, 4, 8):
        many = paged_ops.paged_flash_attention(q, kp, vp, table, positions,
                                               window=window,
                                               kv_splits=splits,
                                               interpret=True)
        err = np.abs(np.asarray(one) - np.asarray(many))[valid].max()
        assert err < 1e-5, (splits, err)


# -- models/attention dispatch ----------------------------------------------


def _tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                qk_norm=False, rope_theta=10000.0)
    base.update(kw)
    return ModelConfig(**base)


def _subjaxprs_of(p):
    if isinstance(p, jax.core.ClosedJaxpr):
        yield p.jaxpr
    elif isinstance(p, jax.core.Jaxpr):
        yield p
    elif isinstance(p, (list, tuple)):
        for q in p:
            yield from _subjaxprs_of(q)


def _all_avals(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            acc.append(tuple(getattr(v.aval, "shape", ())))
        for p in eqn.params.values():
            for sub in _subjaxprs_of(p):
                _all_avals(sub, acc)
    return acc


def test_pallas_path_never_materializes_gathered_context():
    """Jaxpr-level acceptance: with backend='pallas' the attention never
    builds the (B, P*page_size, kv, hd) gathered context (nor its (B, C)-
    scored full tensor); the ref backend (oracle) still does."""
    cfg = _tiny_cfg()
    b, c, ps, p_log = 2, 4, 4, 5
    p = attention.init_attention(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((b, c, cfg.d_model), jnp.float32)
    cache = {"k": jnp.zeros((1 + b * p_log, ps, 2, 8), jnp.float32),
             "v": jnp.zeros((1 + b * p_log, ps, 2, 8), jnp.float32)}
    table = jnp.zeros((b, p_log), jnp.int32)
    positions = jnp.zeros((b, c), jnp.int32)
    n_tokens = jnp.zeros((b,), jnp.int32)
    gathered = (b, p_log * ps, 2, 8)

    def shapes(backend):
        jx = jax.make_jaxpr(
            lambda *a: attention.paged_attention(*a, cfg, backend=backend))(
                p, x, cache, table, positions, n_tokens)
        return _all_avals(jx.jaxpr, [])

    assert gathered in shapes("ref")          # the oracle gathers
    assert gathered not in shapes("pallas")   # the kernel never does


@pytest.mark.parametrize("window", [None, 8])
def test_attention_backends_agree(window):
    cfg = _tiny_cfg(attn_window=window)
    rng = np.random.default_rng(3)
    b, c, ps, p_log = 2, 4, 4, 5
    p = attention.init_attention(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(b, c, cfg.d_model)) * 0.1, jnp.float32)
    cache = {"k": jnp.asarray(rng.normal(size=(1 + b * p_log, ps, 2, 8)),
                              jnp.float32),
             "v": jnp.asarray(rng.normal(size=(1 + b * p_log, ps, 2, 8)),
                              jnp.float32)}
    table = jnp.asarray(
        1 + np.arange(b * p_log, dtype=np.int32).reshape(b, p_log))
    starts = np.asarray([7, 0], np.int32)
    positions = jnp.asarray(starts[:, None] + np.arange(c)[None], jnp.int32)
    n_tokens = jnp.asarray([4, 2], np.int32)

    y_ref, cache_ref = attention.paged_attention(
        p, x, cache, table, positions, n_tokens, cfg, backend="ref")
    y_pal, cache_pal = attention.paged_attention(
        p, x, cache, table, positions, n_tokens, cfg, backend="pallas",
        kv_splits=2)
    valid = np.arange(c)[None, :] < np.asarray(n_tokens)[:, None]
    err = np.abs(np.asarray(y_ref) - np.asarray(y_pal))[valid].max()
    assert err < 1e-4, err
    for k in ("k", "v"):   # the scatter is shared — pools must be identical
        np.testing.assert_array_equal(np.asarray(cache_ref[k]),
                                      np.asarray(cache_pal[k]))


def test_paged_step_backend_symmetry():
    """Model-level: one mixed paged_step tick produces the same last-valid-
    token logits on the pallas (interpret) and ref backends."""
    model = build("smollm-360m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    b, c, ps = 2, 8, 4
    cfg = model.cfg
    p_log = 4
    pools = init_paged_cache(model, 1 + b * p_log, ps)
    table = jnp.asarray(
        1 + np.arange(b * p_log, dtype=np.int32).reshape(b, p_log))
    tokens = jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab, size=(b, c)),
        jnp.int32)
    start = jnp.asarray([0, 0], jnp.int32)
    n_tok = jnp.asarray([8, 3], jnp.int32)

    logits_ref, _ = model.paged_step(params, tokens, pools, table, start,
                                     n_tok, backend="ref")
    logits_pal, _ = model.paged_step(params, tokens, pools, table, start,
                                     n_tok, backend="pallas", kv_splits=2)
    np.testing.assert_allclose(np.asarray(logits_ref),
                               np.asarray(logits_pal), atol=2e-4, rtol=1e-4)
