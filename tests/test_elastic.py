"""Elastic scaling: checkpoint written under one 'mesh', restored with
shardings for another (host-level mechanics; the multi-device behaviour is
covered by the dry-run passing on both 256- and 512-chip meshes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.elastic import rescale_plan, restore_onto_mesh
from repro.distributed.sharding import param_shardings
from repro.launch.mesh import make_host_mesh


def test_restore_onto_mesh_roundtrip(tmp_path):
    mesh = make_host_mesh(1, 1)
    tree = {"layer": {"wi": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(5, tree)
    restored = restore_onto_mesh(ckpt, 5, jax.eval_shape(lambda: tree), mesh)
    np.testing.assert_array_equal(np.asarray(restored["layer"]["wi"]),
                                  np.asarray(tree["layer"]["wi"]))
    want = param_shardings(tree, mesh)["layer"]["wi"]
    assert restored["layer"]["wi"].sharding == want


def test_rescale_plans():
    # grow: 1 pod -> 2 pods
    grow = rescale_plan({"data": 16, "model": 16},
                        {"pod": 2, "data": 16, "model": 16}, 256)
    assert grow["new_dp"] == 32 and grow["per_replica_batch"] == 8
    # shrink that breaks batch divisibility is flagged
    bad = rescale_plan({"data": 16, "model": 16}, {"data": 10, "model": 16},
                       256)
    assert not bad["batch_divisible"]
