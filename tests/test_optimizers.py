"""Prox-ADAM / Prox-RMSProp / Prox-SGD: correctness + convergence (paper
Algorithms 1-2), MM baseline, pruning baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks, metrics, mm, optimizers, pruning


def _lasso_problem(seed=0, n=80, d=24, k=4):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(n, d)) / np.sqrt(d), jnp.float32)
    w_true = np.zeros(d, np.float32)
    w_true[:k] = rng.normal(size=k) * 3
    y = A @ jnp.asarray(w_true)

    def loss(p):
        return 0.5 * jnp.mean((A @ p["w"][:, 0] - y) ** 2)

    return loss, jnp.asarray(w_true), {"w": jnp.zeros((d, 1), jnp.float32)}


@pytest.mark.parametrize("name,lr,kw", [
    ("prox_adam", 2e-2, {}),
    ("prox_rmsprop", 2e-2, {}),
    ("prox_sgd", 1.0, {"momentum": 0.9}),
])
def test_prox_optimizers_solve_lasso(name, lr, kw):
    loss, w_true, params = _lasso_problem()
    opt = optimizers.get_optimizer(name, learning_rate=lr, lam=1e-3, **kw)
    st = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        return opt.update(g, s, p)

    for _ in range(3000):
        params, st = step(params, st)
    w = np.asarray(params["w"][:, 0])
    # support recovery: zeros where w_true is zero
    assert np.all(np.abs(w[4:]) < 0.15), w
    np.testing.assert_allclose(w[:4], np.asarray(w_true)[:4], atol=0.4)


def test_prox_adam_produces_exact_zeros():
    loss, _, params = _lasso_problem()
    opt = optimizers.prox_adam(1e-2, lam=5.0)
    st = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, st = opt.update(g, st, params)
    w = np.asarray(params["w"])
    assert np.sum(w == 0.0) > 0, "soft thresholding must give exact zeros"


def test_adam_matches_reference_update():
    """One Prox-ADAM step vs a hand-rolled ADAM + soft-threshold."""
    params = {"w": jnp.asarray([[1.0, -2.0, 0.3]])}
    g = {"w": jnp.asarray([[0.5, -0.1, 0.9]])}
    lr, lam, b1, b2, eps = 0.1, 0.4, 0.9, 0.999, 1e-8
    opt = optimizers.prox_adam(lr, lam=lam, b1=b1, b2=b2, eps=eps)
    st = opt.init(params)
    p2, _ = opt.update(g, st, params)

    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mhat, vhat = m / (1 - b1), v / (1 - b2)
    z = np.asarray(params["w"]) - lr * mhat / (np.sqrt(vhat) + eps)
    tau = lr * lam
    want = np.sign(z) * np.maximum(np.abs(z) - tau, 0)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, atol=1e-6)


def test_mask_freezes_zeros_in_debias():
    params = {"w": jnp.asarray([[1.0, 0.0, -2.0, 0.0]])}
    mask = masks.zero_mask(params)
    np.testing.assert_allclose(np.asarray(mask["w"]), [[1, 0, 1, 0]])
    opt = optimizers.prox_adam(0.1, lam=0.0)
    st = opt.init(params)
    g = {"w": jnp.ones((1, 4))}
    for _ in range(5):
        params, st = opt.update(g, st, params, mask=mask)
    w = np.asarray(params["w"])
    assert w[0, 1] == 0.0 and w[0, 3] == 0.0
    assert w[0, 0] != 1.0  # surviving weights actually trained


def test_schedule_lambda():
    opt = optimizers.prox_adam(0.1, lam=lambda t: 0.0 * t)
    params = {"w": jnp.ones((2, 2))}
    st = opt.init(params)
    p2, _ = opt.update({"w": jnp.zeros((2, 2))}, st, params)
    # lam=0 => no shrink toward zero beyond the (zero) gradient step
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def test_magnitude_prune_global_rate():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    out = pruning.magnitude_prune_global(params, 0.9)
    rate = metrics.compression_rate(out)
    assert 0.85 <= rate <= 0.95


def test_magnitude_prune_std():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    out = pruning.magnitude_prune_std(params, quality=1.0)
    # ~68% of a gaussian is within 1 std
    rate = metrics.compression_rate(out)
    assert 0.5 < rate < 0.8


def test_mm_converges_on_lasso():
    loss, w_true, params = _lasso_problem()
    cfg = mm.MMConfig(alpha=1e-3, mu0=1e-2, mu_growth=1.2, mu_every=200,
                      c_step_every=200, learning_rate=5e-2, sgd_momentum=0.9)
    st = mm.mm_init(params, cfg)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        return mm.mm_update(g, s, p, cfg)

    for _ in range(2000):
        params, st = step(params, st)
    final = mm.mm_final_params(params, st)
    w = np.asarray(final["w"][:, 0])
    np.testing.assert_allclose(w[:4], np.asarray(w_true)[:4], atol=0.5)
    # theta copy must be sparse on the irrelevant support
    assert np.mean(np.abs(w[4:])) < 0.2


def test_mm_memory_is_double():
    """Paper Table 2: MM needs ~2x the optimizer state of the prox method."""
    params = {"w": jnp.zeros((128, 128))}
    mm_bytes = mm.mm_state_bytes(mm.mm_init(params, mm.MMConfig()))
    opt = optimizers.prox_adam(1e-3, lam=0.1)
    st = opt.init(params)
    prox_bytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves((st.m, st.v)))
    assert mm_bytes >= 1.4 * prox_bytes


def test_compression_metrics_table():
    params = {"a": jnp.asarray([[1.0, 0.0], [0.0, 0.0]]),
              "bias": jnp.zeros((3,))}
    table = metrics.layer_compression(params)
    assert list(table.values())[0]["nnz"] == 1
    total = metrics.total_compression(params)
    assert total["compression_rate"] == 0.75
    assert "bias" not in "".join(table)
