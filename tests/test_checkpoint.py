"""Checkpointing: atomic save/restore, sparse storage, retention, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.optimizers import prox_adam
from repro.train.state import TrainState


def _state(seed=0, d=64):
    rng = np.random.default_rng(seed)
    params = {"layer": {"wi": jnp.asarray(rng.normal(size=(d, d)),
                                          jnp.float32),
                        "bias": jnp.asarray(rng.normal(size=(d,)),
                                            jnp.float32)}}
    opt = prox_adam(1e-3, lam=0.1)
    return TrainState.create(params, opt)


def test_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    state = _state()
    ckpt.save(7, state)
    assert ckpt.latest_step() == 7
    restored = ckpt.restore(7, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sparse_storage_roundtrip(tmp_path):
    """>=70%-sparse weight matrices are stored BCSR and restored exactly."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 128)).astype(np.float32)
    w[rng.random((128, 128)) < 0.9] = 0.0
    tree = {"wi": jnp.asarray(w)}
    ckpt = Checkpointer(str(tmp_path), sparse_storage=True)
    ckpt.save(1, tree)
    man = ckpt.manifest(1)
    fmt = {e["name"]: e["format"] for e in man["leaves"]}
    assert fmt["wi"] == "csr"
    restored = ckpt.restore(1, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["wi"]), w)


def test_sparse_storage_smaller_on_disk(tmp_path):
    rng = np.random.default_rng(1)
    w = rng.normal(size=(512, 512)).astype(np.float32)
    w[rng.random((512, 512)) < 0.95] = 0.0
    dense_dir, sparse_dir = tmp_path / "d", tmp_path / "s"
    Checkpointer(str(dense_dir), sparse_storage=False).save(1, {"wi": jnp.asarray(w)})
    Checkpointer(str(sparse_dir), sparse_storage=True).save(1, {"wi": jnp.asarray(w)})

    def dir_bytes(d):
        return sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(d) for f in fs)

    assert dir_bytes(sparse_dir) < 0.6 * dir_bytes(dense_dir)


def test_retention_gc(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep_n=2)
    state = {"w": jnp.ones((4, 4))}
    for s in [1, 2, 3, 4]:
        ckpt.save(s, state)
    assert ckpt.all_steps() == [3, 4]


def test_restore_with_shardings(tmp_path):
    """Elastic restore path: device_put with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    ckpt = Checkpointer(str(tmp_path))
    tree = {"wi": jnp.ones((8, 8))}
    ckpt.save(1, tree)
    sh = {"wi": NamedSharding(mesh, P(None, None))}
    restored = ckpt.restore(1, jax.eval_shape(lambda: tree), shardings=sh)
    assert restored["wi"].sharding == sh["wi"]


def test_train_loop_resume(tmp_path):
    """Kill/restart: loop resumes from newest checkpoint, same trajectory."""
    from repro.train.loop import LoopConfig, train_loop
    opt = prox_adam(1e-2, lam=0.0)
    A = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32)

    def step(state, batch):
        def loss(p):
            return jnp.mean((A @ p["w"] - batch["y"]) ** 2)
        g = jax.grad(loss)(state.params)
        p2, o2 = opt.update(g, state.opt_state, state.params)
        return TrainState(p2, o2, None, state.step + 1), {"loss": loss(state.params)}

    def batch_fn(s):
        rng = np.random.default_rng(s)
        return {"y": jnp.asarray(rng.normal(size=(16, 1)), jnp.float32)}

    params = {"w": jnp.zeros((8, 1))}
    ckpt = Checkpointer(str(tmp_path))
    s0 = TrainState.create(params, opt)
    # full run
    full, _ = train_loop(step, s0, batch_fn, LoopConfig(total_steps=10,
                                                        ckpt_every=100))
    # interrupted run: 6 steps, checkpoint, then "restart" from scratch
    ckpt2 = Checkpointer(str(tmp_path / "b"))
    part, _ = train_loop(step, s0, batch_fn,
                         LoopConfig(total_steps=6, ckpt_every=3),
                         checkpointer=ckpt2)
    resumed, _ = train_loop(step, s0, batch_fn,
                            LoopConfig(total_steps=10, ckpt_every=100),
                            checkpointer=ckpt2)
    np.testing.assert_allclose(np.asarray(resumed.params["w"]),
                               np.asarray(full.params["w"]), atol=1e-6)
