"""BCSR / CSR format tests: roundtrips + hypothesis property sweeps."""
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import (bcsr_density, bcsr_to_dense, csr_to_dense,
                                  dense_to_bcsr, dense_to_csr)


def _random_block_sparse(rng, rows, cols, block, density):
    br, bc = block
    R, C = -(-rows // br), -(-cols // bc)
    w = np.zeros((R * br, C * bc), np.float32)
    for i in range(R):
        for j in range(C):
            if rng.random() < density:
                w[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = rng.normal(
                    size=(br, bc))
    return w[:rows, :cols]


@hypothesis.given(
    st.integers(1, 5), st.integers(1, 5),
    st.sampled_from([(8, 8), (8, 16), (16, 8)]),
    st.floats(0.0, 1.0), st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=40, deadline=None)
def test_bcsr_roundtrip_property(rb, cb, block, density, seed):
    rng = np.random.default_rng(seed)
    rows, cols = rb * block[0], cb * block[1]
    w = _random_block_sparse(rng, rows, cols, block, density)
    m = dense_to_bcsr(w, block)
    back = np.asarray(bcsr_to_dense(m))[:rows, :cols]
    np.testing.assert_array_equal(back, w)
    assert 0 <= bcsr_density(m) <= 1


def test_bcsr_nonmultiple_shape_pads():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(13, 21)).astype(np.float32)
    m = dense_to_bcsr(w, (8, 8))
    assert m.shape == (13, 21)
    back = np.asarray(bcsr_to_dense(m))[:13, :21]
    np.testing.assert_array_equal(back, w)


def test_bcsr_all_zero():
    m = dense_to_bcsr(np.zeros((16, 16), np.float32), (8, 8))
    assert m.n_blocks == 0
    assert np.all(np.asarray(bcsr_to_dense(m)) == 0)


def test_bcsr_nbytes_smaller_when_sparse():
    rng = np.random.default_rng(2)
    w = _random_block_sparse(rng, 128, 128, (8, 8), 0.1)
    m = dense_to_bcsr(w, (8, 8))
    assert m.nbytes < w.size * 4 * 0.35


@hypothesis.given(st.integers(1, 40), st.integers(1, 40),
                  st.floats(0, 1), st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=40, deadline=None)
def test_csr_roundtrip_property(rows, cols, density, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    w[rng.random((rows, cols)) > density] = 0
    c = dense_to_csr(w)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(c)), w)
    assert c.nnz == np.count_nonzero(w)
