"""BCSR / CSR format tests: roundtrips + property sweeps.

Hypothesis sweeps run when the package is installed; seeded parametrized
fallbacks cover the same roundtrip invariants otherwise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse.formats import (bcsr_density, bcsr_to_dense, csr_to_dense,
                                  dense_to_bcsr, dense_to_csr, pad_bcsr)

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_block_sparse(rng, rows, cols, block, density):
    br, bc = block
    R, C = -(-rows // br), -(-cols // bc)
    w = np.zeros((R * br, C * bc), np.float32)
    for i in range(R):
        for j in range(C):
            if rng.random() < density:
                w[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = rng.normal(
                    size=(br, bc))
    return w[:rows, :cols]


def _bcsr_roundtrip_case(rb, cb, block, density, seed):
    rng = np.random.default_rng(seed)
    rows, cols = rb * block[0], cb * block[1]
    w = _random_block_sparse(rng, rows, cols, block, density)
    m = dense_to_bcsr(w, block)
    back = np.asarray(bcsr_to_dense(m))[:rows, :cols]
    np.testing.assert_array_equal(back, w)
    assert 0 <= bcsr_density(m) <= 1


@pytest.mark.parametrize("seed", range(10))
def test_bcsr_roundtrip_seeded(seed):
    rng = np.random.default_rng(seed)
    rb, cb = int(rng.integers(1, 6)), int(rng.integers(1, 6))
    block = [(8, 8), (8, 16), (16, 8)][seed % 3]
    density = float(rng.uniform(0, 1))
    _bcsr_roundtrip_case(rb, cb, block, density, seed)


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        st.integers(1, 5), st.integers(1, 5),
        st.sampled_from([(8, 8), (8, 16), (16, 8)]),
        st.floats(0.0, 1.0), st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_bcsr_roundtrip_property(rb, cb, block, density, seed):
        _bcsr_roundtrip_case(rb, cb, block, density, seed)

    @hypothesis.given(st.integers(1, 40), st.integers(1, 40),
                      st.floats(0, 1), st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_csr_roundtrip_property(rows, cols, density, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(rows, cols)).astype(np.float32)
        w[rng.random((rows, cols)) > density] = 0
        c = dense_to_csr(w)
        np.testing.assert_array_equal(np.asarray(csr_to_dense(c)), w)
        assert c.nnz == np.count_nonzero(w)


@pytest.mark.parametrize("seed", range(8))
def test_csr_roundtrip_seeded(seed):
    rng = np.random.default_rng(seed)
    rows, cols = int(rng.integers(1, 41)), int(rng.integers(1, 41))
    density = float(rng.uniform(0, 1))
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    w[rng.random((rows, cols)) > density] = 0
    c = dense_to_csr(w)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(c)), w)
    assert c.nnz == np.count_nonzero(w)


def test_bcsr_nonmultiple_shape_pads():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(13, 21)).astype(np.float32)
    m = dense_to_bcsr(w, (8, 8))
    assert m.shape == (13, 21)
    back = np.asarray(bcsr_to_dense(m))[:13, :21]
    np.testing.assert_array_equal(back, w)


def test_bcsr_all_zero():
    m = dense_to_bcsr(np.zeros((16, 16), np.float32), (8, 8))
    assert m.n_blocks == 0
    assert np.all(np.asarray(bcsr_to_dense(m)) == 0)


def test_bcsr_nbytes_smaller_when_sparse():
    rng = np.random.default_rng(2)
    w = _random_block_sparse(rng, 128, 128, (8, 8), 0.1)
    m = dense_to_bcsr(w, (8, 8))
    assert m.nbytes < w.size * 4 * 0.35


def test_pad_bcsr_preserves_dense_equivalent():
    """Padded slots/gather columns are no-ops — the uniform-shape stacking
    trick behind the compressed layer-stack scan."""
    rng = np.random.default_rng(3)
    w = _random_block_sparse(rng, 32, 48, (8, 8), 0.4)
    m = dense_to_bcsr(w, (8, 8))
    p = pad_bcsr(m, m.data.shape[0] + 3, m.gather_idx.shape[1] + 2,
                 m.gather_t_idx.shape[1] + 1)
    np.testing.assert_array_equal(np.asarray(bcsr_to_dense(p))[:32, :48], w)
    assert p.data.shape[0] == m.data.shape[0] + 3
