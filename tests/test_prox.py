"""Unit + property tests for proximal operators (paper §2.2).

Property sweeps run under hypothesis when it is installed; seeded
parametrized fallbacks cover the same invariants otherwise, so the module
always collects (hypothesis is an optional dependency of the container).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _seeded_cases(n=8):
    """(z, tau) pairs mirroring the hypothesis strategies, deterministic."""
    cases = []
    for seed in range(n):
        rng = np.random.default_rng(seed)
        shape = tuple(rng.integers(1, 32, size=rng.integers(1, 4)))
        z = rng.uniform(-100, 100, size=shape).astype(np.float32)
        tau = float(rng.uniform(0, 50))
        cases.append((z, tau))
    return cases


def test_soft_threshold_closed_form():
    z = jnp.asarray([-3.0, -1.0, -0.5, 0.0, 0.5, 1.0, 3.0])
    out = prox.soft_threshold(z, 1.0)
    np.testing.assert_allclose(out, [-2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 2.0])


@pytest.mark.parametrize("z,tau", _seeded_cases())
def test_soft_threshold_is_prox_of_l1_seeded(z, tau):
    got = np.asarray(prox.soft_threshold(jnp.asarray(z), tau))
    want = np.sign(z) * np.maximum(np.abs(z) - tau, 0.0)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("z,tau", _seeded_cases())
def test_prox_nonexpansive_seeded(z, tau):
    """prox operators are 1-Lipschitz (firm nonexpansiveness)."""
    shift = float(np.random.default_rng(int(tau * 1000) % 2**31
                                        ).uniform(-10, 10))
    z2 = z + shift * np.sin(np.arange(z.size, dtype=np.float32)
                            ).reshape(z.shape)
    a = np.asarray(prox.soft_threshold(jnp.asarray(z), tau))
    b = np.asarray(prox.soft_threshold(jnp.asarray(z2), tau))
    assert np.linalg.norm(a - b) <= np.linalg.norm(z - z2) + 1e-4


@pytest.mark.parametrize("z,tau", _seeded_cases())
def test_prox_zero_tau_is_identity_seeded(z, tau):
    # atol covers denormals: XLA flushes subnormals to zero (FTZ)
    np.testing.assert_allclose(
        np.asarray(prox.soft_threshold(jnp.asarray(z), 0.0)), z, atol=1e-37)


if HAVE_HYPOTHESIS:
    floats = hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                     max_side=32),
                        elements=st.floats(-100, 100, width=32))
    taus = st.floats(0, 50, width=32)

    @hypothesis.given(floats, taus)
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_soft_threshold_is_prox_of_l1(z, tau):
        """prox minimizes 0.5||w-z||^2 + tau*||w||_1: check against the
        sign/abs closed form."""
        got = np.asarray(prox.soft_threshold(jnp.asarray(z), tau))
        want = np.sign(z) * np.maximum(np.abs(z) - tau, 0.0)
        np.testing.assert_allclose(got, want, atol=1e-5)

    @hypothesis.given(floats, st.floats(-10, 10, width=32), taus)
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_prox_nonexpansive(z1, shift, tau):
        """prox operators are 1-Lipschitz (firm nonexpansiveness)."""
        z2 = z1 + shift * np.sin(np.arange(z1.size, dtype=np.float32)
                                 ).reshape(z1.shape)
        a = np.asarray(prox.soft_threshold(jnp.asarray(z1), tau))
        b = np.asarray(prox.soft_threshold(jnp.asarray(z2), tau))
        assert np.linalg.norm(a - b) <= np.linalg.norm(z1 - z2) + 1e-4

    @hypothesis.given(floats)
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_prox_zero_tau_is_identity(z):
        # atol covers denormals: XLA flushes subnormals to zero (FTZ)
        np.testing.assert_allclose(
            np.asarray(prox.soft_threshold(jnp.asarray(z), 0.0)), z,
            atol=1e-37)


def test_hard_threshold():
    z = jnp.asarray([-2.0, -0.5, 0.5, 2.0])
    np.testing.assert_allclose(prox.hard_threshold(z, 1.0), [-2, 0, 0, 2.0])


def test_group_l1_blocks_zeroes_whole_blocks():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(8, 8), scale=0.1), jnp.float32)
    out = prox.prox_group_l1_blocks(w, tau=100.0, block=(4, 4))
    assert np.all(np.asarray(out) == 0)
    out2 = prox.prox_group_l1_blocks(w, tau=0.0, block=(4, 4))
    np.testing.assert_allclose(out2, w, rtol=1e-6)


def test_group_l1_partial_blocks():
    w = np.zeros((8, 8), np.float32)
    w[:4, :4] = 10.0          # strong block survives
    w[4:, 4:] = 0.01          # weak block dies
    out = np.asarray(prox.prox_group_l1_blocks(jnp.asarray(w), tau=1.0,
                                               block=(4, 4)))
    assert np.all(out[4:, 4:] == 0)
    assert np.all(out[:4, :4] > 9.0)


def test_tree_prox_skips_biases_and_norms():
    params = {"w": jnp.ones((4, 4)), "bias": jnp.ones((4,)),
              "norm": {"scale": jnp.ones((4,))}}
    out = prox.tree_prox(params, 10.0)
    assert np.all(np.asarray(out["w"]) == 0)
    assert np.all(np.asarray(out["bias"]) == 1)
    assert np.all(np.asarray(out["norm"]["scale"]) == 1)


def test_elastic_net_shrinks_more():
    z = jnp.asarray([[5.0]])
    l1 = prox.soft_threshold(z, 1.0)
    en = prox.prox_elastic_net(z, 1.0, 1.0)
    assert float(en[0, 0]) == pytest.approx(float(l1[0, 0]) / 2.0)
