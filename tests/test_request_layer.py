"""Request-layer invariant suite: refcounted PageAllocator, radix-tree
PrefixCache, and the priority/preemption Scheduler — no model, pure
host-side mechanics.

The load-bearing guarantees:
  * allocator refcounts: shared pages free only at the last owner, double
    frees and trash-page frees are hard errors,
  * radix tree: longest-prefix match at page granularity capped at
    ``len(prompt)-1`` (the last token must run — its logits seed
    sampling), mid-page matches surface a pinned COW source, only
    prompt-immutable pages are ever inserted, eviction touches only
    leaves the tree solely owns (LRU first),
  * priority scheduling: strictly-more-important arrivals preempt the
    least-important youngest slot, preempted requests requeue at the
    FRONT of their class with generated tokens kept and resume by
    re-prefilling prompt + generated as one seq, page shortfall preempts
    (or defers) rather than deadlocks, per-class prefill quotas follow
    ``class_shares``,
  * the seeded ~200-tick stress trace: mixed admit/preempt/finish churn
    with prefix sharing and COW, checked after EVERY tick for the global
    invariants — refcount == #owners (slot page tables + radix tree) for
    every page, trash page 0 never owned, free list and allocated pages
    partition {1..n_pages-1}, and a fully drained system leaks nothing.
"""
import numpy as np
import pytest

from repro.serve.paged_kv import PageAllocator, pages_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import (PRIORITY_CLASSES, Request, Scheduler,
                                   resolve_priority)

PS = 4  # page size used throughout


def _sched(capacity=2, chunk=4, n_pages=64, max_pages=8, budget=None,
           first_chunk=None, prefix_cache=False, class_shares=None):
    alloc = PageAllocator(n_pages)
    pc = PrefixCache(alloc, PS) if prefix_cache else None
    return Scheduler(capacity=capacity, prefill_chunk=chunk,
                     allocator=alloc, page_size=PS, max_pages=max_pages,
                     token_budget=budget, first_chunk=first_chunk,
                     prefix_cache=pc, class_shares=class_shares)


def _req(rid, plen, gen=4, prompt=None, **kw):
    if prompt is None:
        prompt = np.arange(plen, dtype=np.int32)
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=gen, **kw)


def _drive(s, ticks=1, token=7):
    """Run ``ticks`` ticks feeding ``token`` back to every sampled slot,
    honouring the engine contract (drain copies, release pinned sources)."""
    out = []
    for _ in range(ticks):
        plan = s.next_tick()
        if plan is None:
            break
        for src, _ in s.drain_copies():
            s.allocator.free([src])
        out += s.complete_tick(plan, np.full(s.capacity, token))
    return out


# ---------------------------------------------------------------------------
# PageAllocator refcounts
# ---------------------------------------------------------------------------

def test_allocator_refcount_shared_page_lifecycle():
    a = PageAllocator(8)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1
    a.incref(p)                               # second owner (e.g. the tree)
    a.incref(p)                               # third (e.g. a COW pin)
    assert a.refcount(p) == 3
    a.free([p])
    a.free([p])
    assert a.refcount(p) == 1 and a.n_free == 6   # still owned once
    a.free([p])
    assert a.refcount(p) == 0 and a.n_free == 7   # last owner released it


def test_allocator_hard_errors():
    a = PageAllocator(8)
    (p,) = a.alloc(1)
    a.free([p])
    with pytest.raises(AssertionError, match="double free"):
        a.free([p])
    with pytest.raises(AssertionError):
        a.free([0])                           # the trash page is untouchable
    with pytest.raises(AssertionError):
        a.incref(5)                           # unallocated


# ---------------------------------------------------------------------------
# PrefixCache: match / insert / COW / evict
# ---------------------------------------------------------------------------

def _cache(n_pages=32):
    a = PageAllocator(n_pages)
    return PrefixCache(a, PS), a


def test_prefix_match_empty_tree_and_insert_roundtrip():
    pc, a = _cache()
    prompt = np.arange(12, dtype=np.int32)    # 3 full pages
    assert pc.match(prompt) == ([], 0, None)
    pages = a.alloc(3)
    assert pc.insert(prompt, pages) == 3
    for p in pages:
        assert a.refcount(p) == 2             # writer + tree
    a.free(pages)                             # the writing request finishes
    got, n_cached, cow = pc.match(prompt)
    # cap at len-1: the 3rd page covers tokens 8..11, but token 11 must
    # run, so only 2 full pages are shared and page 3 comes back as the
    # COW source for the 3 remaining matchable tokens (8, 9, 10)
    assert got == pages[:2] and n_cached == 11 and cow == pages[2]
    assert a.refcount(cow) == 2               # pinned for the copy
    assert all(a.refcount(p) == 2 for p in got)


def test_prefix_match_mid_page_divergence_cow():
    pc, a = _cache()
    cached = np.asarray([0, 1, 2, 3, 4, 5, 6, 7], np.int32)
    pages = a.alloc(2)
    pc.insert(cached, pages)
    a.free(pages)
    # diverges at token 6: one full shared page + 2 matching head tokens
    # of the second page -> COW
    got, n_cached, cow = pc.match(
        np.asarray([0, 1, 2, 3, 4, 5, 99, 98, 97, 96], np.int32))
    assert got == [pages[0]] and n_cached == 6 and cow == pages[1]
    # diverges at token 0 of the second page: no COW source
    got, n_cached, cow = pc.match(
        np.asarray([0, 1, 2, 3, 99, 98, 97, 96], np.int32))
    assert got == [pages[0]] and n_cached == 4 and cow is None


def test_prefix_insert_rejects_mutable_pages():
    pc, a = _cache()
    with pytest.raises(AssertionError):
        # 2 pages cover 8 tokens but the prompt is 7 long: the second
        # page's tail will still be written by generated tokens
        pc.insert(np.arange(7, dtype=np.int32), a.alloc(2))


def test_prefix_evict_lru_leaves_only():
    pc, a = _cache()
    old = np.arange(8, dtype=np.int32)
    new = np.arange(100, 108, dtype=np.int32)
    p_old, p_new = a.alloc(2), a.alloc(2)
    pc.insert(old, p_old)
    pc.insert(new, p_new)
    a.free(p_old + p_new)                     # the tree is now sole owner
    assert pc.n_cached_pages == 4
    pc.evict(1)                               # coldest leaf: old's 2nd page
    assert pc.n_cached_pages == 3
    assert a.refcount(p_old[1]) == 0 and a.refcount(p_old[0]) == 1
    pc.evict(1)                               # its parent became a leaf
    assert a.refcount(p_old[0]) == 0
    assert sorted(pc.cached_pages()) == sorted(p_new)
    # a page pinned by a running request is never evicted
    got, _, cow = pc.match(np.concatenate([new, [1, 2]]).astype(np.int32))
    assert pc.evict(10) == 0 if cow else True  # all remaining pages shared
    assert set(pc.cached_pages()) == set(p_new)


def test_prefix_hit_rate_accounting():
    pc, a = _cache()
    prompt = np.arange(9, dtype=np.int32)     # 2 full pages + 1 token
    pages = a.alloc(2)
    pc.insert(prompt, pages)
    a.free(pages)
    pc.match(prompt)                          # 8 of 9 tokens hit
    pc.match(np.arange(50, 59, dtype=np.int32))   # miss
    assert pc.n_queries == 2 and pc.n_hit_queries == 1
    assert pc.tokens_hit == 8 and pc.tokens_queried == 18
    assert pc.hit_rate == pytest.approx(8 / 18)


# ---------------------------------------------------------------------------
# Priority classes + preemption
# ---------------------------------------------------------------------------

def test_resolve_priority_names_and_errors():
    assert resolve_priority("interactive") == 0
    assert resolve_priority("batch") == PRIORITY_CLASSES["batch"]
    assert resolve_priority(5) == 5
    with pytest.raises(ValueError):
        resolve_priority("urgent")
    with pytest.raises(ValueError):
        resolve_priority(-1)


def test_admission_preempts_strictly_less_important():
    s = _sched(capacity=1, chunk=8)
    s.add(_req(0, 4, gen=8, priority="batch"))
    _drive(s, 3)                              # batch prefills + decodes
    batch_slot = s.slots[0]
    assert batch_slot.req.rid == 0 and len(batch_slot.generated) >= 1
    gen_before = list(batch_slot.generated)

    s.add(_req(1, 4, gen=2, priority="interactive"))
    plan = s.next_tick()                      # interactive preempts
    assert s.slots[0].req.rid == 1
    assert s.n_preemptions == 1
    # the victim requeued at the FRONT of its class, generated kept
    entry = s.waiting[PRIORITY_CLASSES["batch"]][0]
    assert entry.req.rid == 0 and entry.generated == gen_before
    assert entry.n_preempted == 1
    s.complete_tick(plan, np.full(1, 7))
    _drive(s, 30)
    assert not s.has_work()
    # resume re-prefilled prompt + generated as one seq
    assert s.n_preemptions == 1


def test_equal_class_never_preempts():
    s = _sched(capacity=1, chunk=8)
    s.add(_req(0, 4, gen=6, priority="standard"))
    _drive(s, 2)
    s.add(_req(1, 4, gen=2, priority="standard"))
    s.next_tick()
    assert s.slots[0].req.rid == 0            # FCFS within a class holds
    assert s.n_preemptions == 0


def test_resume_seq_is_prompt_plus_generated():
    s = _sched(capacity=1, chunk=8)
    s.add(_req(0, 6, gen=8, priority="batch"))
    _drive(s, 4, token=9)                     # a few decoded tokens
    gen_before = list(s.slots[0].generated)
    assert gen_before
    s.add(_req(1, 4, gen=1, priority="interactive"))
    _drive(s, 3, token=9)                     # preempt, serve, finish rid 1
    assert not any(sl is not None and sl.req.rid == 1 for sl in s.slots) \
        or s.slots[0].req.rid == 0
    _drive(s, 1, token=9)
    resumed = s.slots[0]
    assert resumed.req.rid == 0
    np.testing.assert_array_equal(
        resumed.seq, np.concatenate([np.arange(6), gen_before]))
    assert resumed.n_gen_at_admit == len(gen_before)
    # ctx accounting: decode resumes exactly where the preemption cut it
    done = _drive(s, 30, token=9)
    assert done and done[0]["rid"] == 0
    assert done[0]["n_generated"] == 8
    assert done[0]["n_preempted"] == 1


def test_page_shortfall_preempts_youngest_less_important():
    # 8 usable pages; two batch requests at 3 pages each fit, then an
    # interactive long request needs 6 -> the youngest batch slot dies
    s = _sched(capacity=3, chunk=8, n_pages=9, max_pages=6)
    s.add(_req(0, 8, gen=4, priority="batch"))
    s.add(_req(1, 8, gen=4, priority="batch"))
    _drive(s, 2)
    assert all(s.slots[i] is not None for i in (0, 1))
    s.add(_req(2, 20, gen=4, priority="interactive"))
    _drive(s, 3)
    assert s.n_preemptions >= 1
    rids = {sl.req.rid for sl in s.slots if sl is not None}
    assert 2 in rids                          # the interactive one is in
    done = _drive(s, 60)
    assert not s.has_work()
    assert s.allocator.n_free == 8            # nothing leaked


def test_prefill_quota_class_shares():
    # two prefilling classes, budget 12 after decode: default shares
    # (2^-0 : 2^-1) give interactive 8 of 12, standard 4
    s = _sched(capacity=2, chunk=8, budget=12)
    s.add(_req(0, 20, gen=2, priority="interactive"))
    s.add(_req(1, 20, gen=2, priority="standard"))
    plan = s.next_tick()
    assert plan.n_tokens.tolist() == [8, 4]
    # explicit shares override: a flat split halves the budget evenly
    s2 = _sched(capacity=2, chunk=8, budget=12,
                class_shares={0: 1.0, 1: 1.0})
    s2.add(_req(0, 20, gen=2, priority="interactive"))
    s2.add(_req(1, 20, gen=2, priority="standard"))
    assert s2.next_tick().n_tokens.tolist() == [6, 6]


def test_page_famine_emits_empty_plan_not_deadlock():
    # one slot holds every usable page; a same-class slot cannot steal
    # them -> its grant defers (n_tokens 0) until the holder finishes
    s = _sched(capacity=2, chunk=8, n_pages=5, max_pages=4, budget=16)
    s.add(_req(0, 13, gen=3))
    _drive(s, 2)                              # rid 0 holds all 4 pages
    assert len(s.slots[0].pages) == 4
    s.add(_req(1, 13, gen=3))
    plan = s.next_tick()
    assert s.slots[1] is not None             # admitted (optimistic) ...
    assert plan.n_tokens[1] == 0              # ... but granted nothing
    done = _drive(s, 40)
    assert not s.has_work()                   # both finish eventually
    assert {d["rid"] for d in done} == {0, 1}


def test_prefix_cache_hit_starts_prefill_past_cached_tokens():
    s = _sched(capacity=2, chunk=8, prefix_cache=True)
    prompt = np.arange(10, dtype=np.int32)    # 2 full pages + 2 tokens
    s.add(_req(0, 0, gen=1, prompt=prompt))
    _drive(s, 3)                              # finish; tree keeps 2 pages
    assert not s.has_work()
    assert s.prefix_cache.n_cached_pages == 2
    s.add(_req(1, 0, gen=1, prompt=prompt))
    plan = s.next_tick()
    sl = s.slots[0]
    assert sl.n_cached == 8 and sl.n_prefilled == 8
    assert plan.start_pos[0] == 8             # prefill resumes mid-prompt
    assert plan.n_tokens[0] == 2
    assert s.allocator.refcount(sl.pages[0]) == 2   # shared with the tree


def test_cow_copy_queued_and_pinned_until_drained():
    s = _sched(capacity=2, chunk=8, prefix_cache=True)
    s.add(_req(0, 8, gen=1))
    _drive(s, 3)
    assert not s.has_work()
    # diverge inside page 2 -> COW: a private dst + a pinned src
    s.add(_req(1, 0, gen=1,
               prompt=np.asarray([0, 1, 2, 3, 4, 5, 99, 98], np.int32)))
    s.next_tick()
    copies = s.drain_copies()
    assert len(copies) == 1
    src, dst = copies[0]
    assert s.allocator.refcount(src) == 2     # tree + the pin
    assert s.allocator.refcount(dst) == 1 and dst in s.slots[0].pages
    s.allocator.free([src])                   # engine releases after copying
    assert s.allocator.refcount(src) == 1     # tree still owns it


# ---------------------------------------------------------------------------
# The seeded stress trace: every-tick invariants under churn
# ---------------------------------------------------------------------------

def _owned_pages(s):
    """page -> #owners from the scheduler's own books: slot page tables
    plus the radix tree. (COW pins are transient — the trace drains them
    within the tick, like the engine does.)"""
    owners: dict[int, int] = {}
    for sl in s.slots:
        if sl is not None:
            assert len(sl.pages) == len(set(sl.pages))   # no dup in a table
            for p in sl.pages:
                owners[p] = owners.get(p, 0) + 1
    if s.prefix_cache is not None:
        for p in s.prefix_cache.cached_pages():
            owners[p] = owners.get(p, 0) + 1
    return owners


def _check_invariants(s, n_pages):
    owners = _owned_pages(s)
    free = set(s.allocator._free)
    assert 0 not in owners and 0 not in free          # trash page untouched
    for p in range(1, n_pages):
        assert s.allocator.refcount(p) == owners.get(p, 0), \
            f"page {p}: refcount {s.allocator.refcount(p)} != " \
            f"{owners.get(p, 0)} owners"
    assert free.isdisjoint(owners)                    # no free-yet-owned
    assert free | set(owners) == set(range(1, n_pages))   # no limbo pages


def test_stress_trace_invariants_every_tick():
    """~200 ticks of seeded churn: random admissions across 3 priority
    classes with shared prefixes (radix hits + COW), random EOS, page
    pressure forcing preemptions — the allocator/scheduler/tree invariants
    hold after every tick and a drained system frees everything."""
    rng = np.random.default_rng(42)
    N_PAGES, CAP = 14, 2
    s = _sched(capacity=CAP, chunk=8, n_pages=N_PAGES, max_pages=5,
               prefix_cache=True)
    # small prompt-prefix pool -> real prefix sharing across requests
    prefixes = [rng.integers(0, 40, 8).astype(np.int32) for _ in range(3)]
    rid, finished, submitted = 0, [], 0
    for tick in range(220):
        if tick < 180 and rng.random() < 0.5:
            prefix = prefixes[rng.integers(len(prefixes))]
            tail = rng.integers(0, 40, rng.integers(1, 6)).astype(np.int32)
            s.add(Request(rid=rid,
                          prompt=np.concatenate([prefix, tail]),
                          max_new_tokens=int(rng.integers(1, 5)),
                          eos_id=3,
                          priority=int(rng.integers(0, 3))))
            rid += 1
            submitted += 1
        plan = s.next_tick()
        if plan is None:
            if submitted == len(finished) and tick >= 180:
                break
            continue
        for src, _ in s.drain_copies():       # the engine contract
            s.allocator.free([src])
        finished += s.complete_tick(
            plan, rng.integers(0, 10, CAP))   # token 3 == EOS sometimes
        _check_invariants(s, N_PAGES)
    assert not s.has_work()                   # the trace drained
    assert len(finished) == submitted == rid
    assert submitted > 40
    # churn actually exercised the interesting paths
    assert s.n_preemptions > 0, "trace never preempted"
    assert s.prefix_cache.tokens_hit > 0, "trace never hit the cache"
    assert any(f["n_preempted"] > 0 for f in finished)
    # drained: only the tree owns pages; evicting it frees every page
    _check_invariants(s, N_PAGES)
    assert s.allocator.n_free == N_PAGES - 1 - s.prefix_cache.n_cached_pages
    s.prefix_cache.evict(N_PAGES)
    assert s.allocator.n_free == N_PAGES - 1  # zero leaks end to end


def test_stress_trace_no_prefix_cache_partition_invariant():
    """Same churn without the tree: free list + slot pages must partition
    the page universe exactly (the PR 5 invariant, now under preemption)."""
    rng = np.random.default_rng(7)
    N_PAGES, CAP = 16, 3
    s = _sched(capacity=CAP, chunk=4, n_pages=N_PAGES, max_pages=4)
    rid, finished, submitted = 0, [], 0
    for tick in range(200):
        if tick < 160 and rng.random() < 0.4:
            s.add(_req(rid, int(rng.integers(1, 12)),
                       gen=int(rng.integers(1, 5)),
                       priority=int(rng.integers(0, 3))))
            rid += 1
            submitted += 1
        plan = s.next_tick()
        if plan is None:
            continue
        finished += s.complete_tick(plan, rng.integers(0, 50, CAP))
        _check_invariants(s, N_PAGES)
    done = True
    while s.has_work():                       # drain the tail
        plan = s.next_tick()
        finished += s.complete_tick(plan, rng.integers(0, 50, CAP))
        _check_invariants(s, N_PAGES)
    assert len(finished) == submitted > 30
    assert s.n_preemptions > 0
    assert s.allocator.n_free == N_PAGES - 1
