"""Deep-compression stage: k-means palette quantization + size accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import model_size_bytes
from repro.core.quantize import (huffman_bits_estimate, kmeans_palette,
                                 quantize_tree, quantized_size_bytes)


def _sparse_weights(seed=0, shape=(64, 64), sparsity=0.9):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=shape).astype(np.float32)
    w[rng.random(shape) < sparsity] = 0.0
    return jnp.asarray(w)


def test_kmeans_preserves_zeros_and_reduces_levels():
    w = _sparse_weights()
    palette, q, assign = kmeans_palette(w, 16)
    assert np.all((np.asarray(w) == 0) == (np.asarray(q) == 0))
    nz_levels = np.unique(np.asarray(q)[np.asarray(q) != 0])
    assert len(nz_levels) <= 16


def test_kmeans_low_distortion():
    w = _sparse_weights(1)
    _, q, _ = kmeans_palette(w, 64)
    nz = np.asarray(w) != 0
    rel = np.linalg.norm(np.asarray(q)[nz] - np.asarray(w)[nz]) / \
        np.linalg.norm(np.asarray(w)[nz])
    assert rel < 0.1


def test_quantize_tree_skips_biases():
    params = {"w": _sparse_weights(2), "bias": jnp.ones((64,))}
    q, report = quantize_tree(params, bits=4)
    assert "w" in "".join(report)
    assert np.array_equal(np.asarray(q["bias"]), np.ones(64))
    assert all(r["rel_err"] < 0.25 for r in report.values())


def test_quantized_size_much_smaller():
    """prune -> quantize -> encode beats CSR alone (the deep-compression
    claim the paper cites as its successor pipeline)."""
    params = {"w": _sparse_weights(3, (256, 256), 0.95)}
    q, report = quantize_tree(params, bits=4)
    dense = model_size_bytes(params, sparse=False)
    csr = model_size_bytes(params, sparse=True)
    dc = quantized_size_bytes(q, bits=4, reports=report)
    assert dc < csr < dense
    assert dense / dc > 10


def test_kmeans_all_zero_layer():
    """A fully pruned layer must not produce NaNs (min/max over an empty
    nonzero set): zero palette, weights unchanged, all assignments 0."""
    w = jnp.zeros((32, 32))
    palette, q, assign = kmeans_palette(w, 16)
    assert np.all(np.isfinite(np.asarray(palette)))
    np.testing.assert_array_equal(np.asarray(palette), np.zeros(16))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((32, 32)))
    np.testing.assert_array_equal(np.asarray(assign), np.zeros(32 * 32))


def test_kmeans_fewer_nonzeros_than_clusters():
    """With fewer distinct nonzeros than clusters the occupied clusters land
    exactly on the values; empty clusters keep their init and go unused."""
    w = np.zeros((16, 16), np.float32)
    w[0, :5] = [-1.0, -0.5, 0.25, 0.75, 1.0]
    palette, q, assign = kmeans_palette(jnp.asarray(w), 64)
    np.testing.assert_allclose(np.asarray(q), w, atol=1e-6)
    assert np.all(np.isfinite(np.asarray(palette)))


def test_kmeans_single_distinct_value():
    w = np.zeros((8, 8), np.float32)
    w[::2] = 0.5
    palette, q, assign = kmeans_palette(jnp.asarray(w), 16)
    np.testing.assert_allclose(np.asarray(q), w, atol=1e-6)


def test_huffman_entropy_bound():
    assign = np.asarray([0] * 90 + [1] * 10)
    nz = np.ones(100, bool)
    bits = huffman_bits_estimate(assign, nz)
    assert 0 < bits < 100            # << 100 * log2(2) uniform bits
    # uniform distribution -> ~1 bit/symbol
    uniform = huffman_bits_estimate(np.asarray([0, 1] * 50), nz)
    assert uniform == pytest.approx(100.0, rel=1e-6)


def test_kmeans_rejects_tracers():
    """kmeans_palette is host-side: calling it under jit tracing (e.g. from
    a sharded jitted step) must fail loudly with guidance, not crash on the
    data-dependent bool() or bake in one branch."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                    jnp.float32)
    with pytest.raises(TypeError, match="host-side"):
        jax.jit(lambda x: kmeans_palette(x, 4)[0])(w)
    # concrete (including sharded-then-gathered) inputs still work
    palette, q, assign = kmeans_palette(w, 4)
    assert np.asarray(palette).shape == (4,)
