"""Multi-replica router: dispatch policies + end-to-end parity.

Unit tests drive ``Router._choose`` / ``_rendezvous`` / ``_affinity_key``
over fake engines (no jax compile): prefix-affinity determinism, the HRW
minimal-remap property on replica death, least-loaded tie-breaking to the
lowest index, round-robin cycling over healthy replicas, backpressure.

Integration tests (real reduced model, BCSR weights) cover the two
load-bearing guarantees: per-token greedy parity through the router with a
forced mid-stream replica failure + re-dispatch (the stitched stream must
match an uninterrupted ``generate()`` run exactly), and prefix-affinity
routing landing every shared-prefix request on one replica's warm cache.
"""
import asyncio
import types

import numpy as np
import pytest

from repro.serve.api import ApiValidationError, Request
from repro.serve.engine import EngineConfig
from repro.serve.router import ROUTE_POLICIES, ReplicaFailed, Router

GEN = 6


# -- fakes: dispatch logic without an engine --------------------------------

class _FakeEngine:
    """Just enough surface for Router dispatch: config + load counters."""

    def __init__(self, config):
        self.config = config
        self.scheduler = types.SimpleNamespace(
            n_reserved_pages=0, n_preemptions=0, has_work=lambda: False)
        self.prefix_cache = None
        self.n_ticks = 0


def _fake_router(n=2, policy="prefix", **kw):
    cfg = EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                       max_seq_len=64)
    return Router([_FakeEngine(cfg) for _ in range(n)], policy=policy, **kw)


def _req(prompt):
    return Request(prompt=prompt, max_new_tokens=4)


def _prompt(seed, length):
    rng = np.random.default_rng(seed)
    return tuple(int(t) for t in rng.integers(0, 1000, size=length))


def test_router_validates_construction():
    with pytest.raises(ApiValidationError, match="at least one replica"):
        Router([])
    with pytest.raises(ApiValidationError, match="route policy"):
        _fake_router(policy="bogus")
    assert set(ROUTE_POLICIES) == {"prefix", "least-loaded", "round-robin"}


def test_affinity_key_is_page_aligned_and_tail_blind():
    r = _fake_router()              # page_size=4, affinity_pages=4 -> 16
    assert r._affinity_key(_prompt(0, 3)) is None       # < one full page
    assert len(r._affinity_key(_prompt(0, 4))) == 4 * 8  # one page, int64
    long = _prompt(1, 40)
    assert r._affinity_key(long) == r._affinity_key(long[:16])
    # the tail beyond the affinity window never enters the key
    assert r._affinity_key(long[:16] + _prompt(2, 10)) \
        == r._affinity_key(long)
    # a different leading page -> a different key
    assert r._affinity_key(_prompt(3, 16)) != r._affinity_key(long)


def test_prefix_affinity_is_deterministic_and_spreads():
    r = _fake_router(n=4)
    picks = {}
    for seed in range(40):
        req = _req(_prompt(seed, 20))
        i = r._choose(req)
        assert r._choose(req) == i          # same prompt -> same replica
        picks[seed] = i
    assert len(set(picks.values())) >= 2    # keys spread over the fleet
    # candidate order is irrelevant to rendezvous hashing
    key = r._affinity_key(_prompt(5, 20))
    assert r._rendezvous(key, [0, 1, 2, 3]) \
        == r._rendezvous(key, [3, 1, 0, 2])


def test_rendezvous_remaps_only_the_dead_replicas_keys():
    r = _fake_router(n=4)
    keys = [r._affinity_key(_prompt(seed, 16)) for seed in range(60)]
    before = {k: r._rendezvous(k, [0, 1, 2, 3]) for k in keys}
    assert set(before.values()) == {0, 1, 2, 3}   # all replicas own keys
    after = {k: r._rendezvous(k, [0, 1, 3]) for k in keys}
    for k in keys:
        if before[k] != 2:                  # survivors keep their keys —
            assert after[k] == before[k]    # their prefix caches stay warm
        else:
            assert after[k] != 2


def test_short_prompt_falls_back_to_least_loaded():
    r = _fake_router()                      # prefix policy
    short = _req(_prompt(0, 3))             # no full page: no affinity key
    assert r._choose(short) == 0            # tie -> lowest index
    r.replicas[0].inflight = 1
    assert r._choose(short) == 1


def test_least_loaded_uses_queue_depth_then_pages():
    r = _fake_router(policy="least-loaded")
    req = _req(_prompt(0, 20))
    assert r._choose(req) == 0              # tie -> lowest index
    r.replicas[0].engine.scheduler.n_reserved_pages = 8
    assert r._choose(req) == 1              # page pressure breaks the tie
    r.replicas[1].inflight = 1              # queue depth dominates pages
    assert r._choose(req) == 0


def test_round_robin_cycles_and_skips_failed():
    r = _fake_router(n=3, policy="round-robin")
    req = _req(_prompt(0, 20))
    assert [r._choose(req) for _ in range(6)] == [0, 1, 2, 0, 1, 2]
    r.replicas[1].failed = True
    assert set(r._choose(req) for _ in range(4)) == {0, 2}


def test_backpressure_waits_for_the_affine_replica():
    r = _fake_router()
    req = _req(_prompt(9, 20))
    i = r._choose(req)
    r.replicas[i].inflight = r.max_inflight
    # the preferred replica is full: wait (None), don't divert — a diverted
    # request would cold-prefill the shared prefix on the other replica
    assert r._choose(req) is None
    r.replicas[i].inflight = 0
    assert r._choose(req) == i


def test_all_replicas_failed_raises():
    r = _fake_router()
    for rep in r.replicas:
        rep.failed = True
    with pytest.raises(ReplicaFailed):
        r._choose(_req(_prompt(0, 20)))


# -- integration: real engines ----------------------------------------------

@pytest.fixture(scope="module")
def model():
    from repro.models.model_zoo import build
    return build("smollm-360m", reduced=True)


@pytest.fixture(scope="module")
def params(model):
    import jax
    from repro.sparse.compress import (CompressionPlan, compress_params,
                                       prune_blocks_for_plan)
    plan = CompressionPlan(block=(8, 64), min_sparsity=0.5)
    pruned = prune_blocks_for_plan(model.init(jax.random.PRNGKey(0)),
                                   plan, 0.85)
    return compress_params(pruned, plan)


def _prompts(lens, vocab, seed=7):
    import jax
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (L,), 0, vocab), np.int32)
            for i, L in enumerate(lens)]


def test_router_failover_keeps_greedy_parity(model, params):
    """2 replicas, forced mid-stream failure of replica 0: every request —
    including the re-dispatched ones — matches an uninterrupted single-model
    ``generate()`` run token for token, and stream indices stay contiguous
    across the move."""
    from repro.serve.step import generate

    config = EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                          max_seq_len=32)
    router = Router.build(model, params, config, 2, policy="least-loaded")
    prompts = _prompts([5, 12, 3, 12, 8, 6], model.cfg.vocab)
    reqs = [Request(prompt=p, max_new_tokens=GEN) for p in prompts]
    events = []

    async def flow():
        await router.start()
        # kill replica 0 once it has streamed 4 tokens (deterministic)
        router.fail_replica_after(0, 4)
        futs = [await router.submit(r, stream=events.append) for r in reqs]
        completions = await asyncio.gather(*futs)
        await router.stop()
        return completions

    completions = asyncio.run(flow())
    stats = router.fleet_stats(completions=completions)
    assert stats["n_failed_replicas"] == 1
    assert stats["n_redispatched"] >= 1     # the failure really moved work

    by_rid = {c.request_id: c for c in completions}
    assert len(by_rid) == len(reqs)
    for rid, p in enumerate(prompts):
        c = by_rid[rid]
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(
            np.asarray(c.tokens), ref,
            err_msg=f"request {rid} (n_redispatched={c.n_redispatched})")
        evs = [e for e in events if e.request_id == rid]
        assert [e.index for e in evs] == list(range(GEN))  # no gap, no dup
        assert [e.token for e in evs] == list(c.tokens)
        assert c.replica == 1 or c.n_redispatched == 0


def test_router_prefix_affinity_lands_on_one_warm_replica(model, params):
    """Requests sharing a (page-aligned, >= affinity window) prefix all
    route to the same replica under the prefix policy, hit its radix cache,
    and still match ``generate()`` exactly."""
    from repro.serve.step import generate

    config = EngineConfig(max_batch=4, prefill_chunk=8, page_size=4,
                          max_seq_len=32, prefix_cache=True)
    router = Router.build(model, params, config, 2, policy="prefix")
    vocab = model.cfg.vocab
    shared = _prompts([16], vocab, seed=3)[0]    # == affinity window (4x4)
    tails = _prompts([4] * 6, vocab, seed=11)
    prompts = [np.concatenate([shared, t]) for t in tails]

    out = router.serve([(p, GEN) for p in prompts])
    per = {r["replica"]: r["n_requests"] for r in out["stats"]["per_replica"]}
    assert sorted(per.values()) == [0, 6]        # all on the affine replica
    assert out["stats"]["n_cached_tokens"] > 0   # ... and its cache was hit
    for rid, p in enumerate(prompts):
        ref = np.asarray(generate(model, params, p[None, :], GEN))[0]
        np.testing.assert_array_equal(out["results"][rid], ref,
                                      err_msg=f"request {rid}")
