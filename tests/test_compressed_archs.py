"""Architecture-complete compression: MoE expert, RWKV time/channel-mix and
RG-LRU projections compress to BlockCSR and serve with logits parity.

Before this, ``compress_params`` only covered attention/MLP/head — the
ROADMAP's "compress MoE expert and RWKV/RG-LRU projections" item. Each
family test prunes a reduced model on the serving BCSR grid, compresses,
and checks prefill + decode parity against the pruned dense model, plus the
format invariants specific to the family (per-expert (L, E) stacks for MoE,
2D transposes for the recurrent projections).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.models.model_zoo import build
from repro.sparse.compress import (CompressionPlan, compress_params,
                                   densify_compressed, make_plan_prox,
                                   prune_blocks_for_plan, quantize_compressed,
                                   split_trainable)
from repro.sparse.formats import BlockCSR, PaletteBCSR

PLAN = CompressionPlan(block=(8, 64), min_sparsity=0.3, min_size=4096)


def _compressed(arch):
    model = build(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    pruned = prune_blocks_for_plan(params, PLAN, 0.75)
    return model, pruned, compress_params(pruned, PLAN)


@pytest.fixture(scope="module")
def moe_setup():
    return _compressed("olmoe-1b-7b")


@pytest.fixture(scope="module")
def rwkv_setup():
    return _compressed("rwkv6-3b")


@pytest.fixture(scope="module")
def rglru_setup():
    return _compressed("recurrentgemma-9b")


def test_moe_experts_compress_per_expert(moe_setup):
    model, pruned, cp = moe_setup
    sp = cp.sparse["layers"]["b0_attn"]["moe"]
    assert set(sp) == {"ewi", "ewg", "ewo"}
    m = sp["ewi"]
    assert isinstance(m, BlockCSR)
    L = model.cfg.n_super_blocks
    E = model.cfg.moe.n_experts
    assert m.data.shape[:2] == (L, E)          # (L, E, slots, br, bc)
    # per-expert slice reproduces that expert's pruned (out, in) view
    ewi = np.asarray(pruned["layers"]["b0_attn"]["moe"]["ewi"])
    sl = jax.tree.map(lambda a: a[1, 2], m)
    dense = np.asarray(sl.to_dense())[:m.shape[0], :m.shape[1]]
    np.testing.assert_array_equal(dense, ewi[1, 2].T)


def test_rwkv_and_rglru_projections_compress(rwkv_setup, rglru_setup):
    _, _, cp_r = rwkv_setup
    layer = cp_r.sparse["layers"]["b0_rwkv"]
    assert {"rwkv_r", "rwkv_k", "rwkv_v", "rwkv_g", "rwkv_o"} \
        <= set(layer["tm"])
    assert {"cm_k", "cm_v", "cm_r"} <= set(layer["cm"])
    _, _, cp_g = rglru_setup
    names = set(cp_g.sparse["layers"]["b0_rglru"]["rec"])
    assert names == {"lru_in", "lru_gate", "lru_out"}
    # remainder (unrolled) RG-LRU layers compress too
    assert any(k.startswith("r") for k in cp_g.sparse.get("rem", {}))


@pytest.mark.parametrize("setup", ["moe_setup", "rwkv_setup", "rglru_setup"])
def test_compressed_matches_pruned_dense(setup, request):
    model, pruned, cp = request.getfixturevalue(setup)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                model.cfg.vocab)
    ld, cache_d = jax.jit(model.prefill)(pruned, prompt,
                                         model.init_cache(2, 16))
    lc, cache_c = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 16))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc),
                               atol=1e-4, rtol=1e-4)
    tok = jnp.argmax(ld, -1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    ld2, _ = step(pruned, tok, cache_d, jnp.int32(8))
    lc2, _ = step(cp, tok, cache_c, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(ld2), np.asarray(lc2),
                               atol=1e-4, rtol=1e-4)


def test_moe_quantized_per_expert_palettes(moe_setup):
    model, _, cp = moe_setup
    qcp = quantize_compressed(cp, bits=8)
    m = qcp.sparse["layers"]["b0_attn"]["moe"]["ewi"]
    assert isinstance(m, PaletteBCSR)
    L, E = m.codes.shape[:2]
    assert m.palette.shape == (L, E, 256)      # a palette per expert slice
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                model.cfg.vocab)
    lc, _ = jax.jit(model.prefill)(cp, prompt, model.init_cache(2, 16))
    lq, _ = jax.jit(model.prefill)(qcp, prompt, model.init_cache(2, 16))
    # 8-bit palette serving tracks the fp compressed logits
    assert float(np.max(np.abs(np.asarray(lc) - np.asarray(lq)))) < 0.5


def test_moe_densify_roundtrip(moe_setup):
    _, pruned, cp = moe_setup
    back = densify_compressed(cp, pruned)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(pruned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_debias_grads_reach_expert_blocks(moe_setup):
    """SpC-Retrain on compressed MoE: grads flow to per-expert BlockCSR.data
    through the lax.map + SDDMM path (resident slots only)."""
    model, _, cp = moe_setup
    trainable, rebuild = split_trainable(cp)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                model.cfg.vocab)

    def loss(tr):
        logits, _ = model.apply_train(rebuild(tr),
                                      {"inputs": prompt, "labels": prompt})
        return jnp.mean(logits.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(trainable)
    for name in ("ewi", "ewg", "ewo"):
        gd = g["bcsr_data"][f"layers/b0_attn/moe/{name}"]
        assert gd.shape == cp.sparse["layers"]["b0_attn"]["moe"][name] \
            .data.shape
        assert float(jnp.linalg.norm(gd)) > 0, name


def test_plan_prox_hits_new_targets():
    """make_plan_prox produces exact zero blocks on the (out, in) grid for
    MoE per-expert and recurrent projection layouts."""
    prox = make_plan_prox(CompressionPlan(block=(8, 64), min_size=4096))
    z = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64, 64)) * 0.05
    out = np.asarray(prox(z, 2.0, path="['layers']['b0_attn']['moe']['ewi']"))
    assert (out == 0).all()                    # tau above every block norm
    out = np.asarray(prox(z, 1e-4, path="['layers']['b0_attn']['moe']['ewi']"))
    assert (out != 0).mean() > 0.99            # tiny tau: shrink only
    z2 = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64)) * 0.05
    for path in ("['layers']['b0_rwkv']['tm']['rwkv_r']",
                 "['layers']['b0_rwkv']['cm']['cm_r']",
                 "['layers']['b0_rglru']['rec']['lru_in']"):
        out = np.asarray(prox(z2, 2.0, path=path))
        assert (out == 0).all(), path
    # non-targets (LoRA, gates, mu vectors) are untouched at any tau
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 32))
    out = np.asarray(prox(v, 100.0, path="['layers']['b0_rwkv']['tm']"
                                         "['lora_w']['lora_a']"))
    np.testing.assert_array_equal(out, np.asarray(v))


def test_moe_compressed_checkpoint_roundtrip(tmp_path, moe_setup):
    _, _, cp = moe_setup
    import dataclasses
    ckpt = Checkpointer(str(tmp_path), keep_n=1)
    ckpt.save(3, cp, extra={"plan": dataclasses.asdict(cp.plan)})
    back = ckpt.restore_compressed(3)
    flat_a, tda = jax.tree_util.tree_flatten(cp)
    flat_b, tdb = jax.tree_util.tree_flatten(back)
    assert tda == tdb
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
