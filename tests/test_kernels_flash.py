"""Flash-attention Pallas kernel vs oracle: shape/dtype/GQA/window sweeps
(interpret mode on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_ref)
from repro.models.attention import chunked_attention


def _ref(q, k, v, causal, window):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    o = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,s,h,kv,hd", [
    (2, 64, 4, 2, 32), (1, 128, 4, 1, 16), (2, 64, 4, 4, 32),
    (1, 64, 8, 2, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(b, s, h, kv, hd, causal):
    rng = np.random.default_rng(hash((b, s, h, kv, hd, causal)) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=16, bk=16)
    want = _ref(q, k, v, causal, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [16, 32])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=32)
    want = _ref(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, bq=16, bk=16)
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), True, None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=0.05, rtol=0.05)


def test_flash_matches_streaming_jnp_attention():
    """The kernel and the model's chunked_attention are interchangeable."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    a = flash_attention(q, k, v, bq=16, bk=16)
    b = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=1e-4)
